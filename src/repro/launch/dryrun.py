import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell, lower + compile the step
(train_step / prefill / decode) against ShapeDtypeStruct inputs on the
single-pod (16x16) and multi-pod (2x16x16) production meshes, then record:

  - memory_analysis()  — per-device bytes (proves it fits),
  - cost_analysis()    — FLOPs / bytes for the roofline,
  - collective bytes   — parsed from the post-SPMD HLO,
  - the derived three-term roofline.

Results land as JSON under experiments/dryrun/; the run is resumable (cells
with existing JSON are skipped unless --force).

NOTE: the XLA_FLAGS line above MUST run before any other import — jax locks
the device count at first init.  Do not set this flag anywhere global.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES, RunConfig, cell_enabled, get_arch
from repro.models import input_specs, make_model
from repro.launch import hlo_cost
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (build_decode_step, build_prefill_step,
                                build_train_step, jit_decode_step,
                                jit_prefill_step, jit_train_step)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             run: RunConfig | None = None, verbose: bool = True,
             mesh_shape: str = "") -> dict:
    cfg = get_arch(arch_name)
    kind, seq, batch = SHAPES[shape_name]
    run = run or RunConfig(seq_len=seq, global_batch=batch, remat="dots")
    if mesh_shape:
        # per-arch mesh factorization (same 256 chips, different DPxTP split)
        dims = tuple(int(x) for x in mesh_shape.split("x"))
        from repro.compat import make_mesh
        mesh = make_mesh(dims, ("data", "model")[:len(dims)])
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    specs = input_specs(cfg, shape_name, run)
    if kind == "train":
        built = build_train_step(cfg, run, mesh)
        params_abs, opt_abs = built["abstract_state"]
        step = jit_train_step(built, mesh, specs["batch"])
        lowered = step.lower(params_abs, opt_abs, specs["batch"],
                             jax.ShapeDtypeStruct((), np.int32))
        tokens = batch * seq
        mflops = RL.train_model_flops(cfg.active_param_count(), tokens)
    elif kind == "prefill":
        built = build_prefill_step(cfg, run, mesh)
        step = jit_prefill_step(built, mesh, specs["batch"],
                                jax.eval_shape(
                                    lambda: make_model(cfg)["init_cache"](
                                        run, batch, seq)))
        lowered = step.lower(built["abstract_params"], specs["batch"])
        mflops = 2.0 * cfg.active_param_count() * batch * seq
    else:  # decode
        built = build_decode_step(cfg, run, mesh)
        step = jit_decode_step(built, mesh, specs["cache"])
        lowered = step.lower(built["abstract_params"], specs["cache"],
                             specs["tokens"], specs["pos"])
        mflops = RL.decode_model_flops(cfg.active_param_count(), batch)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):      # older jax: list of per-device dicts
        cost = cost[0] if cost else {}
    mem = _mem_dict(compiled.memory_analysis())
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    # loop-aware costs (cost_analysis counts while bodies once; see hlo_cost)
    dyn_hint = max(1.0, seq / (2.0 * run.attn_chunk))
    parsed = hlo_cost.analyze(hlo, dynamic_trip_hint=dyn_hint)
    coll = parsed.as_dict()["collectives"]
    coll["total_bytes"] = parsed.as_dict()["collective_bytes"]
    corrected = {"flops": parsed.flops, "bytes accessed": parsed.traffic}
    roof = RL.roofline(corrected, {"total_bytes": coll["total_bytes"]},
                       n_chips, model_flops=mflops)
    roof["dynamic_loops_hinted"] = parsed.dynamic_loops

    result = {
        "arch": arch_name, "shape": shape_name,
        "mesh": mesh_shape or ("2x16x16" if multi_pod else "16x16"),
        "n_chips": n_chips, "step_kind": kind,
        "seq_len": seq, "global_batch": batch,
        "run_config": {"remat": run.remat, "fsdp": run.fsdp,
                       "attn_chunk": run.attn_chunk,
                       "microbatch": run.microbatch, "dtype": run.dtype,
                       "moe_groups": run.moe_groups,
                       "act_shard": run.act_shard},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost_raw": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "optimal_seconds")
                     if k in cost},
        "cost": corrected,
        "collectives": coll,
        "roofline": roof,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if verbose:
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0)) / 1e9
        print(f"[dryrun] {arch_name:22s} {shape_name:12s} "
              f"{'multi' if multi_pod else 'single':6s} "
              f"OK  mem/dev={hbm:7.2f}GB  "
              f"compute={roof['compute_s']:.3e}s "
              f"mem={roof['memory_s']:.3e}s "
              f"coll={roof['collective_s']:.3e}s "
              f"bott={roof['bottleneck']:10s} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--moe-groups", type=int, default=0)
    ap.add_argument("--act-shard", default="none")
    ap.add_argument("--bf16-scores", action="store_true")
    ap.add_argument("--mesh-shape", default="",
                    help='custom DPxTP factorization, e.g. "64x4"')
    ap.add_argument("--tag", default="", help="suffix for output JSONs")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for a in archs:
        for s in shapes:
            ok, why = cell_enabled(ARCHS[a], s)
            if not ok:
                print(f"[dryrun] {a:22s} {s:12s} SKIP   ({why})")
                n_skip += 1
                continue
            for mp in meshes:
                tag = f"{a}__{s}__{'multi' if mp else 'single'}"
                if args.tag:
                    tag += "__" + args.tag
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    n_skip += 1
                    continue
                kind, seq, batch = SHAPES[s]
                run = RunConfig(seq_len=seq, global_batch=batch,
                                remat=args.remat, fsdp=args.fsdp,
                                microbatch=args.microbatch,
                                moe_groups=args.moe_groups,
                                act_shard=args.act_shard,
                                attn_f32_scores=not args.bf16_scores)
                try:
                    res = run_cell(a, s, mp, run=run,
                                   mesh_shape=args.mesh_shape)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    n_ok += 1
                except Exception as e:
                    n_fail += 1
                    print(f"[dryrun] {a:22s} {s:12s} "
                          f"{'multi' if mp else 'single':6s} FAIL  {e}")
                    traceback.print_exc()
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
