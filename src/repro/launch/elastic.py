"""Elastic scaling: re-plan the mesh + shardings for a changed device count
and resume from the newest checkpoint.

On a real cluster the controller detects lost/added slices and relaunches the
job with a different device set; everything the job needs to continue is
(a) a mesh factorization for the new count, (b) re-derived shardings (the
Rules are mesh-parametric), and (c) the latest complete checkpoint (host
arrays, so they reshard on device_put).  Tests simulate this with fake CPU
devices: train on 8, "lose" half, resume on 4 — loss continues descending.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.compat import make_mesh
from repro.configs import RunConfig


def factor_counts(n_devices: int, want_model: int = 0) -> tuple[int, int]:
    """The ``(data, model)`` axis sizes :func:`factor_mesh` realizes.
    Greedy: model axis gets the largest power-of-2 divisor of ``n_devices``
    that is ``<= want_model`` — which may be *smaller* than ``want_model``
    (n=6, want_model=4 -> model=2, data=3), so validation must run against
    this, not against the request."""
    model = 1
    if want_model > 1:
        m = min(want_model, n_devices)
        while m > 1:
            if n_devices % m == 0:
                model = m
                break
            m //= 2
    return n_devices // model, model


def factor_mesh(n_devices: int, want_model: int = 0):
    """Choose a (data, model) factorization for an arbitrary device count
    (:func:`factor_counts`) and build the mesh."""
    data, model = factor_counts(n_devices, want_model)
    return make_mesh((data, model), ("data", "model"))


def remesh_and_resume(cfg, run: RunConfig, checkpoint_dir: str,
                      n_devices: int | None = None, want_model: int = 0,
                      steps: int = 10):
    """Rebuild on a new mesh and continue training from the checkpoint.

    Batch divisibility is validated against the factorization
    :func:`factor_mesh` will actually pick — not the requested
    ``want_model``, which it may round down — so an invalid config fails
    here with the real numbers instead of deep inside ``train``."""
    from .train import train
    devs = jax.devices()
    n = n_devices or len(devs)
    data, model = factor_counts(n, want_model)
    if run.global_batch % data:
        raise ValueError(
            f"global batch {run.global_batch} not divisible by the data-"
            f"parallel degree {data} ({n} devices factor as data={data} x "
            f"model={model} for want_model={want_model})")
    mesh = factor_mesh(n, want_model)
    return train(cfg, run, steps, mesh=mesh, checkpoint_dir=checkpoint_dir,
                 checkpoint_every=max(steps // 2, 1))


def remesh_and_resume_svi(model, engine_cfg, checkpoint_dir: str,
                          n_devices: int | None = None, want_model: int = 0):
    """Statistical-engine counterpart of :func:`remesh_and_resume`: factor
    a mesh for the surviving device count, wrap its data axis in an
    inferspark :class:`~repro.core.partition.ShardingPlan`, and continue
    the SVI fit from ``checkpoint_dir``'s newest valid
    :class:`~repro.checkpoint.TrainSession`.

    ``engine_cfg`` is anything :func:`~repro.core.engine.make_engine`
    accepts (its ``steps`` is the *total* budget — only the remainder past
    the session's step runs).  Unlike the LM path there is no
    batch-divisibility constraint: SVI LPT-packs each minibatch across the
    data shards by token mass.  The session fingerprint deliberately
    excludes the sharding plan, so resuming on a *different* device count
    is allowed — the schedule (sampler, Robbins-Monro position, holdout)
    continues exactly, but cross-shard reduction order changes, so the
    continuation is deterministic-going-forward rather than bitwise to the
    old mesh.  At an unchanged device count it is bitwise (the crash-test
    suite's contract).
    """
    from repro.core.engine import make_engine
    from repro.core.partition import ShardingPlan

    n = n_devices or len(jax.devices())
    data, model_ax = factor_counts(n, want_model)
    mesh = make_mesh((data, model_ax), ("data", "model"))
    plan = ShardingPlan(mesh, ("data",), "inferspark")
    eng = make_engine(engine_cfg, sharding=plan,
                      checkpoint_dir=checkpoint_dir, resume=True)
    return eng.fit(model)
