"""Elastic scaling: re-plan the mesh + shardings for a changed device count
and resume from the newest checkpoint.

On a real cluster the controller detects lost/added slices and relaunches the
job with a different device set; everything the job needs to continue is
(a) a mesh factorization for the new count, (b) re-derived shardings (the
Rules are mesh-parametric), and (c) the latest complete checkpoint (host
arrays, so they reshard on device_put).  Tests simulate this with fake CPU
devices: train on 8, "lose" half, resume on 4 — loss continues descending.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.compat import make_mesh
from repro.configs import RunConfig


def factor_counts(n_devices: int, want_model: int = 0) -> tuple[int, int]:
    """The ``(data, model)`` axis sizes :func:`factor_mesh` realizes.
    Greedy: model axis gets the largest power-of-2 divisor of ``n_devices``
    that is ``<= want_model`` — which may be *smaller* than ``want_model``
    (n=6, want_model=4 -> model=2, data=3), so validation must run against
    this, not against the request."""
    model = 1
    if want_model > 1:
        m = min(want_model, n_devices)
        while m > 1:
            if n_devices % m == 0:
                model = m
                break
            m //= 2
    return n_devices // model, model


def factor_mesh(n_devices: int, want_model: int = 0):
    """Choose a (data, model) factorization for an arbitrary device count
    (:func:`factor_counts`) and build the mesh."""
    data, model = factor_counts(n_devices, want_model)
    return make_mesh((data, model), ("data", "model"))


def remesh_and_resume(cfg, run: RunConfig, checkpoint_dir: str,
                      n_devices: int | None = None, want_model: int = 0,
                      steps: int = 10):
    """Rebuild on a new mesh and continue training from the checkpoint.

    Batch divisibility is validated against the factorization
    :func:`factor_mesh` will actually pick — not the requested
    ``want_model``, which it may round down — so an invalid config fails
    here with the real numbers instead of deep inside ``train``."""
    from .train import train
    devs = jax.devices()
    n = n_devices or len(devs)
    data, model = factor_counts(n, want_model)
    if run.global_batch % data:
        raise ValueError(
            f"global batch {run.global_batch} not divisible by the data-"
            f"parallel degree {data} ({n} devices factor as data={data} x "
            f"model={model} for want_model={want_model})")
    mesh = factor_mesh(n, want_model)
    return train(cfg, run, steps, mesh=mesh, checkpoint_dir=checkpoint_dir,
                 checkpoint_every=max(steps // 2, 1))


def remesh_and_resume_svi(model, engine_cfg, checkpoint_dir: str,
                          n_devices: int | None = None, want_model: int = 0):
    """Statistical-engine counterpart of :func:`remesh_and_resume`: factor
    a mesh for the surviving device count, wrap its data axis in an
    inferspark :class:`~repro.core.partition.ShardingPlan`, and continue
    the SVI fit from ``checkpoint_dir``'s newest valid
    :class:`~repro.checkpoint.TrainSession`.

    ``engine_cfg`` is anything :func:`~repro.core.engine.make_engine`
    accepts (its ``steps`` is the *total* budget — only the remainder past
    the session's step runs).  Unlike the LM path there is no
    batch-divisibility constraint: SVI LPT-packs each minibatch across the
    data shards by token mass.  The session fingerprint deliberately
    excludes the sharding plan, so resuming on a *different* device count
    is allowed — the schedule (sampler, Robbins-Monro position, holdout)
    continues exactly, but cross-shard reduction order changes, so the
    continuation is deterministic-going-forward rather than bitwise to the
    old mesh.  At an unchanged device count it is bitwise (the crash-test
    suite's contract).
    """
    from repro.core.engine import make_engine
    from repro.core.partition import ShardingPlan

    n = n_devices or len(jax.devices())
    data, model_ax = factor_counts(n, want_model)
    mesh = make_mesh((data, model_ax), ("data", "model"))
    plan = ShardingPlan(mesh, ("data",), "inferspark")
    eng = make_engine(engine_cfg, sharding=plan,
                      checkpoint_dir=checkpoint_dir, resume=True)
    return eng.fit(model)


def multihost_svi_session(model, engine_cfg, corpus_dir: str,
                          checkpoint_dir: str | None = None, *,
                          n_hosts: int | None = None,
                          host_id: int | None = None,
                          coordinator: str | None = None,
                          ownership_seed: int = 0):
    """One host's entry point into a multi-host SVI fit over a partitioned
    corpus — the distributed analogue of :func:`remesh_and_resume_svi`.

    With ``coordinator`` ("host:port") the process first joins the
    ``jax.distributed`` cluster as process ``host_id`` of ``n_hosts``
    (CPU collectives via :func:`repro.compat.distributed_initialize`).
    In a multi-process runtime the corpus is opened through a
    :class:`~repro.data.HostAssignment` view, so this host mmaps only the
    shards it owns; single-process callers get ``n_hosts`` *virtual* hosts
    over the local devices (same partitioned batching, unrestricted I/O).

    The mesh is the full global device set on one ``("data",)`` axis.
    With ``checkpoint_dir`` the fit resumes from the newest valid session
    (host 0 is the sole writer; all hosts read — shared-filesystem
    contract), which is how an elastic remesh works here: relaunch every
    surviving/new host with the new ``n_hosts`` and the same
    ``checkpoint_dir``/``ownership_seed``; shard ownership re-derives from
    the new topology (HRW hashing moves only the minimal shards) and the
    schedule continues exactly — deterministic-going-forward, bitwise when
    the global device count is unchanged.  See ``docs/distributed.md``.
    """
    from repro.checkpoint import latest_session_step
    from repro.core.engine import make_engine
    from repro.core.partition import ShardingPlan
    from repro.data import HostAssignment, ShardedCorpus

    if coordinator is not None:
        from repro.compat import distributed_initialize
        if n_hosts is None or host_id is None:
            raise ValueError("coordinator= needs explicit n_hosts/host_id")
        distributed_initialize(coordinator_address=coordinator,
                               num_processes=n_hosts, process_id=host_id)
    multiproc = jax.process_count() > 1
    if n_hosts is None:
        n_hosts = jax.process_count()
    if host_id is None:
        host_id = jax.process_index() if multiproc else 0
    hosts = HostAssignment(n_hosts, host_id, ownership_seed)
    # real multi-process runs restrict corpus I/O to owned shards; a
    # single process simulating n virtual hosts must keep all shards
    # readable (SVI rejects a restricted view in virtual mode)
    corpus = ShardedCorpus.open(corpus_dir, hosts=hosts if multiproc
                                else None)
    mesh = make_mesh((jax.device_count(),), ("data",))
    plan = ShardingPlan(mesh, ("data",), "inferspark")
    resume = bool(checkpoint_dir
                  and latest_session_step(checkpoint_dir) is not None)
    eng = make_engine(engine_cfg, sharding=plan, corpus=corpus,
                      hosts=hosts, checkpoint_dir=checkpoint_dir,
                      resume=resume)
    return eng.fit(model)
