"""Elastic scaling: re-plan the mesh + shardings for a changed device count
and resume from the newest checkpoint.

On a real cluster the controller detects lost/added slices and relaunches the
job with a different device set; everything the job needs to continue is
(a) a mesh factorization for the new count, (b) re-derived shardings (the
Rules are mesh-parametric), and (c) the latest complete checkpoint (host
arrays, so they reshard on device_put).  Tests simulate this with fake CPU
devices: train on 8, "lose" half, resume on 4 — loss continues descending.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.compat import make_mesh
from repro.configs import RunConfig


def factor_mesh(n_devices: int, want_model: int = 0):
    """Choose a (data, model) factorization for an arbitrary device count.
    Greedy: model axis gets the largest power-of-2 divisor <= want_model."""
    model = 1
    if want_model > 1:
        m = min(want_model, n_devices)
        while m > 1:
            if n_devices % m == 0:
                model = m
                break
            m //= 2
    data = n_devices // model
    return make_mesh((data, model), ("data", "model"))


def remesh_and_resume(cfg, run: RunConfig, checkpoint_dir: str,
                      n_devices: int | None = None, want_model: int = 0,
                      steps: int = 10):
    """Rebuild on a new mesh and continue training from the checkpoint."""
    from .train import train
    devs = jax.devices()
    n = n_devices or len(devs)
    if run.global_batch % n and run.global_batch % (n // max(want_model, 1)):
        raise ValueError(f"global batch {run.global_batch} not divisible "
                         f"for {n} devices")
    mesh = factor_mesh(n, want_model)
    return train(cfg, run, steps, mesh=mesh, checkpoint_dir=checkpoint_dir,
                 checkpoint_every=max(steps // 2, 1))
