"""Loop-aware cost extraction from compiled HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which
undercounts every scanned structure we use (layer stacks, flash-attention
chunk loops, SSD chunk scans) — verified empirically (scan of 8 matmuls
reports 1 matmul of FLOPs).  This module parses the post-SPMD HLO text and
aggregates, with loop trip counts taken from each while op's
``backend_config={"known_trip_count":{"n":...}}``:

  - dot FLOPs        (2 * prod(out) * prod(lhs contracting dims)),
  - HBM traffic      (sum of operand+output bytes of materializing ops:
                      fusions, dots, copies, slices, collectives — the same
                      read-once/write-once model XLA's own analysis uses),
  - collective bytes (by kind: all-gather / all-reduce / reduce-scatter /
                      all-to-all / collective-permute).

Dynamic-bound loops (the causal prefill skip) carry no known_trip_count; the
caller provides a hint (average triangular trip count).
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# tuple types may contain /*index=N*/ comments (with '='), never parens
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\]{},\s]+?)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops considered to materialize their operands/outputs in HBM
_TRAFFIC_OPS = {
    "fusion", "dot", "copy", "dynamic-slice", "dynamic-update-slice",
    "gather", "scatter", "reduce", "sort", "transpose", "broadcast",
    "concatenate", "slice", "pad", "reverse", "convolution", "iota",
    "reduce-window", "select-and-scatter", "rng", "cholesky",
    "triangular-solve", "convert",
} | set(COLLECTIVES)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, ()
    dt, dims = m.group(1), m.group(2)
    return dt, tuple(int(d) for d in dims.split(",")) if dims else (dt, ())


@dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    rest: str                     # operand list + attributes
    operands: list = field(default_factory=list)


@dataclass
class _Comp:
    name: str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)     # name -> type str


def _split_operands(rest: str) -> tuple[list[str], str]:
    """Split the top-level operand list 'a, b, c), attrs...' -> names.

    Operands may carry inline types (older HLO emitters: ``f32[4,64]{1,0}
    %arg``) whose brackets/braces contain commas, so depth tracks all three
    bracket kinds."""
    depth = 0
    out, cur = [], []
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            if depth == 0:
                out.append("".join(cur).strip())
                return [o for o in out if o], rest[i + 1:]
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    return [o for o in out if o], ""


def _operand_name(operand: str) -> str:
    """'f32[4,64]{1,0} %get-tuple-element.4' or '%x' or 'x' -> symbol name."""
    return operand.split()[-1].lstrip("%") if operand else ""


def parse_computations(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = _Comp(m.group(1))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, kind, tail = m.groups()
        operands, attrs = _split_operands(tail)
        op = _Op(name, type_str.strip(), kind, attrs)
        op.operands = operands
        cur.symbols[name] = op.type_str
        cur.ops.append(op)
    return comps


def _dot_flops(op: _Op, comp: _Comp) -> float:
    _, out_dims = _shape_dims(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if not m or not op.operands:
        return 0.0
    lhs_name = _operand_name(op.operands[0])
    lhs_type = comp.symbols.get(lhs_name, "")
    _, lhs_dims = _shape_dims(lhs_type)
    contract = 1
    for d in (m.group(1).split(",") if m.group(1) else []):
        i = int(d)
        if i < len(lhs_dims):
            contract *= lhs_dims[i]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * contract


def _op_traffic(op: _Op, comp: _Comp, with_operands: bool = False) -> int:
    """Write-once traffic model: every materializing op writes its output;
    reads are the producers' writes (so not double counted) except for dots
    and collectives, which stream their operands from HBM again."""
    total = _shape_bytes(op.type_str)
    if with_operands:
        for o in op.operands:
            o = _operand_name(o)
            if o in comp.symbols:
                total += _shape_bytes(comp.symbols[o])
            elif "[" in o:            # inline-typed operand, type is the name
                total += _shape_bytes(o)
    return total


def _while_info(op: _Op):
    body = cond = None
    m = re.search(r"body=%?([\w.\-]+)", op.rest)
    if m:
        body = m.group(1)
    m = re.search(r"condition=%?([\w.\-]+)", op.rest)
    if m:
        cond = m.group(1)
    trip = None
    m = re.search(r'known_trip_count"?\s*:\s*\{"?n"?\s*:\s*"?(\d+)"?', op.rest)
    if m:
        trip = int(m.group(1))
    return body, cond, trip


def _fusion_callee(op: _Op):
    m = re.search(r"calls=%?([\w.\-]+)", op.rest)
    return m.group(1) if m else None


@dataclass
class Costs:
    flops: float = 0.0
    traffic: int = 0
    coll: dict = field(default_factory=lambda: {k: 0 for k in COLLECTIVES})
    coll_count: dict = field(default_factory=lambda: {k: 0 for k in COLLECTIVES})
    dynamic_loops: int = 0

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic += int(other.traffic * mult)
        for k in COLLECTIVES:
            self.coll[k] += int(other.coll[k] * mult)
            self.coll_count[k] += int(other.coll_count[k] * mult)
        self.dynamic_loops += other.dynamic_loops

    def as_dict(self) -> dict:
        total = sum(self.coll.values())
        return {"flops": self.flops, "traffic_bytes": self.traffic,
                "collectives": {k: {"bytes": self.coll[k],
                                    "count": self.coll_count[k]}
                                for k in COLLECTIVES},
                "collective_bytes": total,
                "dynamic_loops": self.dynamic_loops}


def analyze(hlo: str, entry: str | None = None,
            dynamic_trip_hint: float = 1.0) -> Costs:
    comps = parse_computations(hlo)
    # fused subcomputations are charged through their fusion op
    fused = set()
    for c in comps.values():
        for op in c.ops:
            if op.kind == "fusion":
                callee = _fusion_callee(op)
                if callee:
                    fused.add(callee)

    memo: dict[str, Costs] = {}

    def comp_cost(name: str) -> Costs:
        if name in memo:
            return memo[name]
        memo[name] = Costs()          # guard cycles
        c = comps.get(name)
        if c is None:
            return memo[name]
        out = Costs()
        for op in c.ops:
            if op.kind == "dot":
                out.flops += _dot_flops(op, c)
                out.traffic += _op_traffic(op, c, with_operands=True)
            elif op.kind in COLLECTIVES or \
                    any(op.kind.startswith(k + "-") for k in COLLECTIVES):
                kind = next(k for k in COLLECTIVES
                            if op.kind == k or op.kind.startswith(k + "-"))
                b = _shape_bytes(op.type_str)
                out.coll[kind] += b
                out.coll_count[kind] += 1
                out.traffic += _op_traffic(op, c, with_operands=True)
            elif op.kind == "while":
                body, cond, trip = _while_info(op)
                if trip is None:
                    trip = dynamic_trip_hint
                    out.dynamic_loops += 1
                sub = Costs()
                if body:
                    sub.add(comp_cost(body))
                if cond:
                    sub.add(comp_cost(cond))
                out.add(sub, trip)
            elif op.kind in ("call", "conditional", "async-start"):
                for target in re.findall(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                         op.rest):
                    out.add(comp_cost(target))
            elif op.kind == "fusion":
                callee = _fusion_callee(op)
                if callee and callee in comps:
                    # count internal dot flops; traffic comes from the
                    # fusion op itself (read-once/write-once)
                    inner = comps[callee]
                    for iop in inner.ops:
                        if iop.kind == "dot":
                            out.flops += _dot_flops(iop, inner)
                out.traffic += _op_traffic(op, c)
            elif op.kind in _TRAFFIC_OPS:
                out.traffic += _op_traffic(op, c)
        memo[name] = out
        return out

    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
        entry = m.group(1) if m else next(iter(comps))
    return comp_cost(entry)
