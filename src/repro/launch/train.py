"""End-to-end fault-tolerant trainer.

Production behaviors, all exercised by tests/examples on CPU:
  - explicit shardings from ``shardings.Rules`` on whatever mesh exists,
  - checkpoint-every-k with atomic commit + crash resume (bitwise: the data
    pipeline is seekable by step),
  - step-time telemetry with straggler/outlier detection,
  - elastic restart: on a device-count change, re-plan the mesh + shardings
    and restore the same checkpoint (see ``elastic.py``).

Usage (example driver):
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50 \
      --d-model 256 --layers 4 --seq 256 --batch 8
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs import RunConfig, get_arch
from repro.data import TokenStream
from repro.launch.mesh import axis_size, data_axes, make_host_mesh
from repro.launch.shardings import named
from repro.launch.steps import build_train_step, jit_train_step
from repro.models import make_model


class StepTelemetry:
    """Step-time tracker; flags outlier steps (the straggler signal that a
    real cluster controller would act on)."""

    def __init__(self, window: int = 50):
        self.times: list[float] = []
        self.window = window
        self.stragglers = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:-1]
        if len(hist) >= 10 and dt > 3.0 * float(np.median(hist)):
            self.stragglers += 1
            return True
        return False

    def summary(self) -> dict:
        arr = np.array(self.times[1:] or [0.0])
        return {"steps": len(self.times),
                "mean_s": float(arr.mean()),
                "p50_s": float(np.percentile(arr, 50)),
                "p95_s": float(np.percentile(arr, 95)),
                "stragglers": self.stragglers}


def train(cfg, run: RunConfig, steps: int, mesh=None,
          checkpoint_dir: str | None = None, checkpoint_every: int = 0,
          log_every: int = 10, start_step: int | None = None):
    """Returns (params, opt_state, losses, telemetry)."""
    mesh = mesh or make_host_mesh()
    built = build_train_step(cfg, run, mesh)
    model = make_model(cfg)

    dp = axis_size(mesh, data_axes(mesh))
    stream = TokenStream(vocab=cfg.vocab, seq_len=run.seq_len,
                         batch=run.global_batch, seed=run.seed)

    # init or resume
    store = None
    resume_step = 0
    params = opt_state = None
    if checkpoint_dir:
        store = CheckpointStore(checkpoint_dir, every=max(checkpoint_every, 1))
        latest = store.latest()
        if latest is not None:
            abs_p, abs_o = built["abstract_state"]
            tree = store.restore({"params": abs_p, "opt": abs_o,
                                  "step": jax.ShapeDtypeStruct((), np.int32)})
            params, opt_state = tree["params"], tree["opt"]
            resume_step = int(tree["step"])
    if params is None:
        params = model["init"](run, jax.random.PRNGKey(run.seed))
        from repro.optim import adamw_init
        opt_state = adamw_init(params)
    if start_step is not None:
        resume_step = start_step

    p_sh = named(mesh, built["params_spec"])
    o_sh = named(mesh, built["opt_spec"])
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    batch0 = stream.batch_at(0)
    batch_abs = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch0)
    step_fn = jit_train_step(built, mesh, batch_abs)
    b_sh = named(mesh, built["batch_specs"](batch_abs))

    telemetry = StepTelemetry()
    losses = []
    for i in range(resume_step, resume_step + steps):
        batch = jax.device_put(stream.batch_at(i), b_sh)
        t0 = time.time()
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jax.numpy.int32(i))
        loss = float(metrics["loss"])
        dt = time.time() - t0
        straggle = telemetry.record(dt)
        losses.append(loss)
        if store is not None and checkpoint_every and \
                (i + 1) % checkpoint_every == 0:
            host = jax.tree_util.tree_map(np.asarray,
                                          {"params": params, "opt": opt_state,
                                           "step": np.int32(i + 1)})
            store.maybe_save(i + 1, host)
        if log_every and (i % log_every == 0 or straggle):
            print(f"[train] step {i:5d} loss {loss:8.4f} "
                  f"{dt*1e3:7.1f} ms{'  STRAGGLER' if straggle else ''}")
    if store is not None:
        store.wait()              # final async checkpoint durable on return
    return params, opt_state, losses, telemetry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.layers or args.d_model:
        cfg = dataclasses.replace(
            cfg,
            n_layers=args.layers or cfg.n_layers,
            d_model=args.d_model or cfg.d_model,
            n_heads=max(4, (args.d_model or cfg.d_model) // 64),
            n_kv_heads=max(2, (args.d_model or cfg.d_model) // 128),
            head_dim=64, d_ff=4 * (args.d_model or cfg.d_model),
            vocab=min(cfg.vocab, 32000))
    run = RunConfig(seq_len=args.seq, global_batch=args.batch,
                    dtype="float32")
    _, _, losses, tel = train(cfg, run, args.steps,
                              checkpoint_dir=args.ckpt_dir,
                              checkpoint_every=args.ckpt_every)
    print(f"[train] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    print(f"[train] telemetry {tel.summary()}")


if __name__ == "__main__":
    main()
