"""Production meshes.

Single pod: a v5e pod of 256 chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the pod axis
carries data parallelism whose collectives cross the inter-pod links (DCN/
optical), so shardings keep param all-gathers *within* a pod (fsdp uses the
intra-pod "data" axis only).

Functions, not module constants: importing this module never touches jax
device state (device count is locked at first jax init, and the 512-device
dry-run must set XLA_FLAGS before that).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=None):
    """Small mesh over however many (possibly fake) devices exist — used by
    tests, benchmarks, and the elastic re-mesh path."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes carrying data parallelism (pod folds into DP)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None


def axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))
