"""Per-shape collective histogram from a compiled cell — the profiling tool
for the hillclimb loop (no real hardware: the lowered IR is the profile).

  PYTHONPATH=src python -m repro.launch.collective_histo --arch gemma3-4b \
      --shape train_4k [--multi] [--remat dots] [--fsdp] [--top 15]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import collections
import re

from . import hlo_cost


def histogram(hlo: str, dynamic_trip_hint: float = 1.0):
    """Trip-count-aware (kind, shape) -> (count, bytes) histogram."""
    comps = hlo_cost.parse_computations(hlo)
    out = collections.Counter()
    bytes_out = collections.Counter()

    memo = {}

    def walk(name, mult):
        c = comps.get(name)
        if c is None:
            return
        for op in c.ops:
            kind = None
            for k in hlo_cost.COLLECTIVES:
                if op.kind == k or op.kind.startswith(k + "-"):
                    kind = k
            if kind:
                shape = op.type_str.strip()
                key = (kind, shape)
                out[key] += mult
                bytes_out[key] += mult * hlo_cost._shape_bytes(shape)
            elif op.kind == "while":
                body, cond, trip = hlo_cost._while_info(op)
                t = trip if trip is not None else dynamic_trip_hint
                if body:
                    walk(body, mult * t)
                if cond:
                    walk(cond, mult * t)
            elif op.kind in ("call", "conditional"):
                for target in re.findall(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                         op.rest):
                    walk(target, mult)

    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    walk(m.group(1) if m else next(iter(comps)), 1.0)
    return out, bytes_out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--moe-groups", type=int, default=0)
    ap.add_argument("--act-shard", default="none")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    from repro.configs import SHAPES, RunConfig
    from repro.launch.dryrun import run_cell

    kind, seq, batch = SHAPES[args.shape]
    run = RunConfig(seq_len=seq, global_batch=batch, remat=args.remat,
                    fsdp=args.fsdp, moe_groups=args.moe_groups,
                    act_shard=args.act_shard)
    # run_cell keeps the HLO internally; easier to re-lower here:
    import jax
    import numpy as np
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (build_decode_step, build_prefill_step,
                                    build_train_step, jit_decode_step,
                                    jit_prefill_step, jit_train_step)
    from repro.models import input_specs, make_model
    from repro.configs import get_arch

    cfg = get_arch(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi)
    specs = input_specs(cfg, args.shape, run)
    if kind == "train":
        built = build_train_step(cfg, run, mesh)
        pa, oa = built["abstract_state"]
        step = jit_train_step(built, mesh, specs["batch"])
        lowered = step.lower(pa, oa, specs["batch"],
                             jax.ShapeDtypeStruct((), np.int32))
    elif kind == "prefill":
        built = build_prefill_step(cfg, run, mesh)
        step = jit_prefill_step(built, mesh, specs["batch"],
                                jax.eval_shape(lambda: make_model(cfg)[
                                    "init_cache"](run, batch, seq)))
        lowered = step.lower(built["abstract_params"], specs["batch"])
    else:
        built = build_decode_step(cfg, run, mesh)
        step = jit_decode_step(built, mesh, specs["cache"])
        lowered = step.lower(built["abstract_params"], specs["cache"],
                             specs["tokens"], specs["pos"])
    hlo = lowered.compile().as_text()
    counts, byts = histogram(hlo, max(1.0, seq / (2.0 * run.attn_chunk)))
    rows = sorted(byts.items(), key=lambda kv: -kv[1])[:args.top]
    total = sum(byts.values())
    print(f"total collective bytes/device: {total/1e9:.2f} GB")
    for (kind_, shape), b in rows:
        print(f"  {b/1e9:9.3f} GB  x{counts[(kind_, shape)]:<8.0f} "
              f"{kind_:20s} {shape[:110]}")


if __name__ == "__main__":
    main()
