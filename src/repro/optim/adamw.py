"""AdamW + global-norm clipping + warmup-cosine schedule + optional int8
error-feedback gradient compression for the data-parallel all-reduce.

All states are pytrees shaped like the params, so the sharding rules that
place the params place the optimizer states identically (ZeRO-style when
``fsdp`` is on: states live sharded over the data axis with the params).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lr_schedule(step, base_lr: float, warmup: int, total: int = 100_000):
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)
    return {"mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0):
    count = state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        step = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
        newp = p.astype(jnp.float32) - lr * (step + weight_decay * p)
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}


# ---------------------------------------------------------------------------
# int8 error-feedback compression (optional DP all-reduce trick)
# ---------------------------------------------------------------------------

def compress_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_decompress(grads, residual):
    """Quantize grad+residual to int8 per-tensor scale; return the
    dequantized value and the new residual (error feedback).  Used before a
    DP all-reduce to cut its bytes 4x; the residual keeps the bias bounded."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), x - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    deq = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    res = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return deq, res
