"""Version compatibility shims for the jax API surface this repo uses.

The codebase targets the modern jax API (``jax.shard_map``,
``jax.sharding.AxisType``, ``check_vma``); older runtimes (0.4.x) spell
these ``jax.experimental.shard_map.shard_map``, have no axis types, and
call the replication check ``check_rep``.  Every mesh/shard_map construction
in the repo goes through these two helpers so the rest of the code can be
written against one API.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    _AxisType = None


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the runtime supports
    them (explicit-sharding-safe) and plain axes elsewhere."""
    if _AxisType is not None:
        return jax.make_mesh(shape, axes, devices=devices,
                             axis_types=(_AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, devices=devices)


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map`` (old),
    with the replication/VMA check disabled under either spelling."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def distributed_initialize(coordinator_address: str, num_processes: int,
                           process_id: int) -> None:
    """``jax.distributed.initialize`` with CPU cross-process collectives
    enabled first.

    On the CPU backend multi-process psums need the gloo collectives
    implementation; without ``jax_cpu_collectives_implementation = "gloo"``
    set *before* initialization, every collective (and even the implicit
    ``assert_equal`` inside multi-process ``device_put``) fails with
    "Multiprocess computations aren't implemented on the CPU backend".
    Newer jax versions default to gloo and may drop the option, so a
    missing config name is ignored.
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # pragma: no cover - depends on installed jax
        pass
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
