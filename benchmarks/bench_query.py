"""Query/serving-layer benchmark: fold-in throughput and compile behavior.

Protocol: fit a short SVI run on a planted corpus, freeze the posterior,
then measure the query layer the way a server exercises it —

  - **cold vs warm compile**: first score at a fresh length bucket (pays
    the jit) vs the same bucket warm (the steady serving state);
  - **batched fold-in throughput sweep**: B unseen documents scored as one
    batch, B in {1, 8, 32, 128} — the padded-bucket batched dispatch the
    QueryServer amortizes compiles and python/dispatch overhead with;
  - **one-doc-at-a-time baseline**: the same documents scored
    individually (warm cache, same bucket — purely the batching win).

The headline derived number, ``batched_speedup_x`` on the
``query_foldin_batched_vs_single`` row, is the acceptance bar for the
serving layer (warm batched >= 5x one-at-a-time).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import make_engine, models
from repro.data import SyntheticCorpus
from repro.query import FoldIn, FoldInConfig

K, V = 16, 2000
N_TRAIN_DOCS = 600
N_QUERY_DOCS = 128
LOCAL_ITERS = 5


def _fit_posterior():
    corpus = SyntheticCorpus(n_docs=N_TRAIN_DOCS, vocab=V, n_topics=K,
                             mean_len=120, seed=0).generate()
    m = models.make("lda", alpha=0.1, beta=0.05, K=K, V=V)
    m["x"].observe(corpus["tokens"], segment_ids=corpus["doc_ids"])
    result = make_engine("svi", steps=30, batch_size=128, seed=0).fit(m)
    return result.freeze(m)


def _query_docs():
    unseen = SyntheticCorpus(n_docs=N_QUERY_DOCS, vocab=V, n_topics=K,
                             mean_len=120, seed=7).generate()
    offs = np.concatenate([[0], np.cumsum(unseen["lengths"])])
    docs = [unseen["tokens"][offs[i]:offs[i + 1]]
            for i in range(N_QUERY_DOCS)]
    return docs, unseen["lengths"]


def run(report):
    post = _fit_posterior()
    docs, lengths = _query_docs()

    fold = FoldIn(post, FoldInConfig(local_iters=LOCAL_ITERS))

    # cold vs warm: one batch shape, first call compiles
    batch32 = np.concatenate(docs[:32])
    t0 = time.time()
    fold.score(batch32, lengths=lengths[:32])
    cold = time.time() - t0
    t0 = time.time()
    r = fold.score(batch32, lengths=lengths[:32])
    warm = time.time() - t0
    report("query_foldin_cold_compile", cold * 1e6,
           f"docs=32;buckets={fold.compiled_buckets}")
    report("query_foldin_warm", warm * 1e6,
           f"docs=32;warm_speedup={cold / max(warm, 1e-9):.1f}x;"
           f"per_token_ll={r.per_token_ll:.4f}",
           cold_us=round(cold * 1e6, 2),
           warm_speedup_x=round(cold / max(warm, 1e-9), 2))

    # batched throughput sweep (warm: one priming call per bucket)
    tput = {}
    for b in (1, 8, 32, 128):
        vals = np.concatenate(docs[:b])
        lens = lengths[:b]
        fold.score(vals, lengths=lens)               # prime the bucket
        iters = max(2, 64 // b)
        t0 = time.time()
        for _ in range(iters):
            fold.score(vals, lengths=lens)
        dt = (time.time() - t0) / iters
        tput[b] = b / dt
        report(f"query_foldin_batch{b:03d}", dt * 1e6,
               f"docs_per_s={tput[b]:.1f};"
               f"tokens={int(lens.sum())}",
               docs_per_s=round(tput[b], 2), batch_docs=b)

    # one-doc-at-a-time baseline: same 32 docs, individually, warm
    for d in docs[:32]:
        fold.score(d)                                # prime every bucket
    t0 = time.time()
    for d in docs[:32]:
        fold.score(d)
    dt_single = time.time() - t0
    single_tput = 32 / dt_single
    report("query_foldin_one_at_a_time", dt_single / 32 * 1e6,
           f"docs_per_s={single_tput:.1f}",
           docs_per_s=round(single_tput, 2))

    best = max(tput.values())
    speedup = best / single_tput
    report("query_foldin_batched_vs_single", 0.0,
           f"batched_speedup_x={speedup:.1f};"
           f"best_batched_docs_per_s={best:.1f};"
           f"single_docs_per_s={single_tput:.1f};"
           f"compiled_buckets={fold.compiled_buckets}",
           batched_speedup_x=round(speedup, 2),
           best_batched_docs_per_s=round(best, 2),
           single_docs_per_s=round(single_tput, 2))
