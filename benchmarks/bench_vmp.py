"""Paper Figure 17 + Table 4: overall time and per-stage breakdown for LDA,
SLDA, DCMLDA — plus the MLlib-style EM-LDA baseline (section 5.1).

Stage names follow Table 4: B.N. Construction / Code Generation /
MPG Construction / Inference.  Here they map to: DSL->network build,
trace+jit compile, observe+layout (device placement), and the iteration loop.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import models
from repro.core.baselines import em_lda
from repro.data import SyntheticCorpus


def _corpus(n_docs, vocab, topics, mean_len, seed=0):
    return SyntheticCorpus(n_docs=n_docs, vocab=vocab, n_topics=topics,
                           mean_len=mean_len, seed=seed).generate()


def _run_model(name, corpus, K, V, iters=10, **extra):
    t0 = time.time()
    m = models.make(name, alpha=0.1, beta=0.05, K=K, V=V)
    t_build = time.time() - t0

    t0 = time.time()
    if name == "slda":
        # sentences of ~7 tokens within docs
        n = len(corpus["tokens"])
        sent_of_tok = np.arange(n) // 7
        doc_of_sent = corpus["doc_ids"][::7][:sent_of_tok.max() + 1]
        m["x"].observe(corpus["tokens"], segment_ids=sent_of_tok.astype(np.int32))
        m.bind("sents", doc_of_sent)
    else:
        m["x"].observe(corpus["tokens"], segment_ids=corpus["doc_ids"])
    prog = m.compile()
    t_observe = time.time() - t0

    t0 = time.time()
    m.infer(steps=1)                       # includes jit compile
    t_compile = time.time() - t0
    t0 = time.time()
    m.infer(steps=iters)
    t_infer = time.time() - t0
    return {"build_s": t_build, "metadata_s": t_observe,
            "codegen_s": t_compile, "infer_s": t_infer,
            "per_iter_s": t_infer / iters,
            "elbo": m.lower_bound, "n_tokens": len(corpus["tokens"])}


def run(report):
    K, V = 16, 2000
    corpus = _corpus(n_docs=400, vocab=V, topics=K, mean_len=120)
    n = len(corpus["tokens"])

    for name in ("lda", "slda", "dcmlda"):
        r = _run_model(name, corpus, K, V)
        report(f"vmp_{name}_per_iter", r["per_iter_s"] * 1e6,
               f"tokens={n};elbo={r['elbo']:.0f};"
               f"words_per_s={n / r['per_iter_s']:.0f}")
        report(f"vmp_{name}_breakdown_us", r["codegen_s"] * 1e6,
               f"build={r['build_s']*1e3:.1f}ms;meta={r['metadata_s']*1e3:.1f}ms;"
               f"codegen={r['codegen_s']*1e3:.1f}ms;"
               f"infer10={r['infer_s']*1e3:.1f}ms")

    # EM-LDA (MLlib analogue): faster per iteration, MAP-only
    t0 = time.time()
    em_lda(corpus["tokens"], corpus["doc_ids"], K, V, iters=10)
    t_em = (time.time() - t0) / 10
    report("vmp_em_lda_baseline_per_iter", t_em * 1e6,
           f"map_only=true;words_per_s={n / t_em:.0f}")
