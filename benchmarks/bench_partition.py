"""Paper Figure 20 + Tables 1-2: partitioning strategies.

Analytic part: E[replications of a data vertex] and E[largest partition]
for 1D/2D/RVC/CRVC/InferSpark at the paper's regime (K=O(1) and K=O(M)),
plus the per-iteration communication volume of each runtime layout.

Measured part (subprocess, 8 fake devices): wall time per VMP iteration and
HLO collective bytes for the three runtime strategies — the TPU analogue of
Figure 20 (tailor-made layout vs generic partitioner vs replicated), plus
the Infer.NET-style replicated memory model (the paper's 512GB anecdote).
"""

from __future__ import annotations

import os
import subprocess
import sys

from repro.core.partition import strategy_costs

_MEASURE_SNIPPET = r"""
import os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.compat import make_mesh
from repro.core import models
from repro.core.partition import ShardingPlan
from repro.data import SyntheticCorpus
from repro.launch import hlo_cost

corpus = SyntheticCorpus(n_docs=400, vocab=2000, n_topics=16,
                         mean_len=120, seed=0).generate()
mesh = make_mesh((8,), ("data",))
for strat in ("inferspark", "gspmd", "replicated"):
    m = models.make("lda", alpha=0.1, beta=0.05, K=16, V=2000)
    m["x"].observe(corpus["tokens"], segment_ids=corpus["doc_ids"])
    plan = None if strat == "replicated" else ShardingPlan(mesh, ("data",), strat)
    m.infer(steps=2, sharding=plan)
    t0 = time.time()
    m.infer(steps=10, sharding=plan)
    dt = (time.time() - t0) / 10
    print(f"MEASURE {strat} {dt*1e6:.1f}")
"""


def run(report):
    # Tables 1-2 at a paper-like operating point
    n, d, k_small, m = 2_596_155, 50_000, 10, 96     # DCMLDA 1% wiki row
    for k, tag in ((k_small, "K_O1"), (m, "K_OM")):
        costs = strategy_costs(n, d, k, m)
        for strat, c in costs.items():
            report(f"partition_{tag}_{strat}", c["E_NB"],
                   f"E_Nxi={c['E_Nxi']:.2f};n={n};k={k};m={m}")

    # replicated-layout memory model (Infer.NET anecdote): bytes for the
    # full MPG state on ONE machine vs the co-partitioned layout per shard
    K, V = 96, 9040                                   # paper's LDA setting
    n_wiki3pct = 8_100_000                            # ~3% wiki words
    repl_bytes = (n_wiki3pct * K * 4                  # responsibilities
                  + n_wiki3pct * 2 * 4                # tokens + doc ids
                  + K * V * 4 * 2)                    # phi posterior+stats
    shard_bytes = repl_bytes / 96 + K * V * 4 * 2
    report("partition_replicated_state_bytes", repl_bytes / 1e6,
           "layout=single_machine;unit=MB")
    report("partition_inferspark_state_bytes", shard_bytes / 1e6,
           "layout=per_shard_96;unit=MB")

    # measured: the three runtime strategies on 8 fake devices
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    try:
        out = subprocess.run([sys.executable, "-c", _MEASURE_SNIPPET],
                             capture_output=True, text=True, timeout=1200,
                             env=env)
        for line in out.stdout.splitlines():
            if line.startswith("MEASURE"):
                _, strat, us = line.split()
                report(f"partition_measured_{strat}", float(us),
                       "devices=8;model=lda_16x2000")
    except Exception as e:                            # pragma: no cover
        report("partition_measured_error", 0.0, str(e)[:60])
