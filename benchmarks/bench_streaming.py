"""Always-on loop: append-while-training + hot artifact refresh under load.

The streaming acceptance bar (ISSUE 6 / ROADMAP "online inference with hot
model refresh"), in two acts:

1. *Append-while-training* — a :class:`ShardedCorpusWriter` keeps
   committing document chunks on a background thread while growing-mode
   SVI trains on the same directory.  The growing sampler re-snapshots the
   population each epoch (corpus ``refresh()``), so appended documents
   enter the schedule live; the fit must reach the held-out per-token ELBO
   target a *resident* fit of the complete corpus sets (within TOL), with
   the corpus reaching its full size mid-run.  Reported: steps/time to
   target, population trajectory, commits observed.
2. *Hot refresh under load* — a :class:`QueryServer` with concurrent
   client threads survives >= 3 artifact hot-swaps (built warm via
   ``FoldIn.with_posterior``): zero dropped or unresolved requests, every
   response names the artifact version that scored it.  Reported: swap
   install latency (swap() call -> first response scored by the new
   artifact), requests in flight at swap time, throughput, compiled
   buckets (warm swaps add none).
"""

from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core import SVI, SVIConfig, make_engine, models
from repro.core.engine import InferenceResult
from repro.data import ShardedCorpusWriter
from repro.query import FoldIn, FoldInConfig, QueryClient, QueryServer

TOL = 0.05            # nats/token slack on the resident target
K, V = 8, 1000
ALPHA, BETA, MEAN_LEN = 0.1, 0.05, 100
INIT_DOCS = 600       # committed before training starts
CHUNK_DOCS = 200      # appended live, per commit
N_CHUNKS = 4          # -> final corpus 1400 docs
CAPACITY = 2048       # pre-allocated local-row ceiling (no retrace)
N_SWAPS = 3
N_CLIENTS = 4


def _corpus(seed: int = 0):
    """The full planted-topic corpus (generated once; streamed in pieces)."""
    rng = np.random.default_rng(seed)
    phi = rng.dirichlet(np.full(V, BETA), size=K)
    phi_cdf = np.cumsum(phi, axis=1)
    n_docs = INIT_DOCS + N_CHUNKS * CHUNK_DOCS
    theta = rng.dirichlet(np.full(K, ALPHA), size=n_docs)
    lengths = np.maximum(rng.poisson(MEAN_LEN, size=n_docs), 2) \
        .astype(np.int64)
    n = int(lengths.sum())
    z = np.empty(n, np.int32)
    start = 0
    for d, ln in enumerate(lengths):
        z[start:start + ln] = rng.choice(K, size=ln, p=theta[d])
        start += ln
    u = rng.random(n)
    tokens = np.empty(n, np.int32)
    for k in range(K):
        m = z == k
        tokens[m] = np.searchsorted(phi_cdf[k], u[m]).astype(np.int32)
    return np.minimum(tokens, V - 1), lengths


def _model():
    return models.make("lda", alpha=ALPHA, beta=BETA, K=K, V=V)


def run(report):
    tokens, lengths = _corpus()
    offs = np.concatenate([[0], np.cumsum(lengths)])
    tmp = tempfile.mkdtemp(prefix="bench_streaming_")
    try:
        # -- resident target: the same complete corpus, fit in one piece
        m = _model()
        m["x"].observe(tokens, lengths=lengths)
        t0 = time.time()
        res = make_engine("svi", steps=80, batch_size=128, local_iters=3,
                          holdout_frac=0.02, holdout_every=10,
                          seed=0).fit(m)
        target = res.heldout_elbo
        report("streaming_resident_target", (time.time() - t0) * 1e6 / 80,
               f"target={target:.4f};docs={len(lengths)}")

        # -- append-while-training
        w = ShardedCorpusWriter(os.path.join(tmp, "corpus"),
                                shard_tokens=1 << 15, vocab=V)
        w.add_docs(tokens[:offs[INIT_DOCS]], lengths[:INIT_DOCS])
        corpus = w.commit()
        commits = {"n": 1}
        done = threading.Event()

        def appender():
            for i in range(N_CHUNKS):
                time.sleep(0.75)        # commits land mid-training
                lo = INIT_DOCS + i * CHUNK_DOCS
                hi = lo + CHUNK_DOCS
                w.add_docs(tokens[offs[lo]:offs[hi]], lengths[lo:hi])
                w.commit()
                commits["n"] += 1
            done.set()

        cfg = SVIConfig(batch_size=128, local_iters=3, holdout_frac=0.02,
                        holdout_every=10, pad_multiple=1024, seed=0,
                        growing=True, capacity_docs=CAPACITY)
        svi = SVI(_model(), cfg, corpus=corpus)
        thread = threading.Thread(target=appender, daemon=True)
        t0 = time.time()
        thread.start()
        state, reached, steps_done, h = None, None, 0, float("-inf")
        while steps_done < 400 and (reached is None or not done.is_set()):
            state, hist = svi.fit(steps=10, state=state)
            steps_done += 10
            h = hist["heldout"][-1][1]
            if reached is None and done.is_set() and h >= target - TOL:
                reached = steps_done
        thread.join()
        t_fit = time.time() - t0
        svi.close()
        log = svi.sampler._inner.epoch_log()
        pops = [p for _, p in log]
        report("streaming_fit_to_target", t_fit / max(steps_done, 1) * 1e6,
               f"steps={reached};heldout={h:.4f};target={target:.4f};"
               f"pop_start={pops[0]};pop_end={pops[-1]};"
               f"commits={commits['n']};fit_s={t_fit:.1f}")
        assert reached is not None, (
            f"growing SVI missed target {target:.4f} (got {h:.4f})")
        assert pops[-1] > pops[0], "corpus never grew during training"

        # -- hot refresh under concurrent load
        def freeze(st, note):
            posts = {n: np.asarray(p) for n, p in st.posteriors.items()}
            r = InferenceResult("svi", posts, [], [], {"note": note})
            return r.freeze(_model(), program=svi.program, note=note)

        early = SVI(_model(), cfg, corpus=corpus)   # an "older" artifact
        mid_state, _ = early.fit(steps=5)
        early.close()
        artifacts = [freeze(mid_state, "early"), freeze(state, "final")]
        fold = FoldIn(artifacts[0], FoldInConfig(local_iters=2))
        srv = QueryServer(fold, max_batch_docs=16,
                          max_delay_s=0.002).start()
        client = QueryClient(srv, timeout_s=120)
        docs = [tokens[offs[i]:offs[i + 1]] for i in range(32)]
        results, errors = [], []
        rlock = threading.Lock()
        stop_flag = threading.Event()

        def drive(i):
            j = 0
            while not stop_flag.is_set():
                try:
                    r = client.score(docs[(i + j) % len(docs)])
                    with rlock:
                        results.append(r)
                except Exception as e:
                    errors.append(e)
                j += 1

        threads = [threading.Thread(target=drive, args=(i,), daemon=True)
                   for i in range(N_CLIENTS)]
        for t in threads:
            t.start()

        def first_response_at(ver, deadline_s=60.0):
            deadline = time.time() + deadline_s
            while time.time() < deadline:
                with rlock:
                    if any(r.artifact_version == ver for r in results):
                        return time.time()
                time.sleep(0.001)
            raise AssertionError(f"version {ver} never served")

        first_response_at("v0")
        cur = fold
        swap_lat, inflight = [], []
        for s in range(N_SWAPS):
            cur = cur.with_posterior(artifacts[(s + 1) % 2])
            inflight.append(srv.stats()["queue_depth"] + N_CLIENTS)
            t_swap = time.time()
            ver = srv.swap(cur)
            swap_lat.append(first_response_at(ver) - t_swap)
        time.sleep(0.2)                 # post-swap traffic on the last artifact
        stop_flag.set()
        for t in threads:
            t.join()
        srv.stop()
        stats = srv.stats()
        assert not errors, f"requests failed during swaps: {errors[:3]}"
        versions = {r.artifact_version for r in results}
        assert versions == {"v0", "v1", "v2", "v3"}, versions
        assert stats["swaps"] == N_SWAPS
        assert cur._fns is fold._fns    # swaps stayed warm (shared cache)
        report("streaming_swap_install", float(np.mean(swap_lat)) * 1e6,
               f"swaps={N_SWAPS};lat_ms=" +
               "/".join(f"{x * 1e3:.1f}" for x in swap_lat) +
               f";inflight={max(inflight)};dropped=0")
        report("streaming_serving", 1e6 / max(stats["docs_per_s"], 1e-9),
               f"requests={stats['requests']};docs_per_s="
               f"{stats['docs_per_s']:.0f};"
               f"p50_ms={stats['latency_p50_ms']:.2f};"
               f"buckets={stats['compiled_buckets']};"
               f"unresolved=0")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
