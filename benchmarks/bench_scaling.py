"""Paper Figures 18 (scale-up: data size) and 19 (scale-out: cluster size).

Scale-up runs LDA per-iteration time against growing corpora in-process.
Scale-out launches subprocesses with 1/2/4/8 fake CPU devices (device count
locks at first jax init) and measures the inferspark-strategy step time.
"""

from __future__ import annotations

import os
import subprocess
import sys
import time

from repro.core import models
from repro.data import SyntheticCorpus

_SCALE_OUT_SNIPPET = r"""
import os, sys, time
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import jax, numpy as np
from repro.compat import make_mesh
from repro.core import models
from repro.core.partition import ShardingPlan
from repro.data import SyntheticCorpus

n_dev = int(sys.argv[1])
corpus = SyntheticCorpus(n_docs=600, vocab=2000, n_topics=16,
                         mean_len=120, seed=0).generate()
m = models.make("lda", alpha=0.1, beta=0.05, K=16, V=2000)
m["x"].observe(corpus["tokens"], segment_ids=corpus["doc_ids"])
mesh = make_mesh((n_dev,), ("data",))
plan = ShardingPlan(mesh, ("data",), "inferspark")
m.infer(steps=2, sharding=plan)          # warmup + compile
t0 = time.time()
m.infer(steps=10, sharding=plan)
print("PER_ITER_US", (time.time() - t0) / 10 * 1e6)
"""


def run(report):
    # Figure 18: scale-up.  The 2400/4800-doc points (4-8x the seed sweep's
    # max) exist because the fused zstats substep dropped the (N, K) arrays
    # from the step's working set — see docs/performance.md.
    for n_docs in (150, 300, 600, 2400, 4800):
        corpus = SyntheticCorpus(n_docs=n_docs, vocab=2000, n_topics=16,
                                 mean_len=120, seed=0).generate()
        m = models.make("lda", alpha=0.1, beta=0.05, K=16, V=2000)
        m["x"].observe(corpus["tokens"], segment_ids=corpus["doc_ids"])
        m.infer(steps=2)
        t0 = time.time()
        m.infer(steps=8)
        dt = (time.time() - t0) / 8
        report(f"vmp_scaleup_{len(corpus['tokens'])}tok", dt * 1e6,
               f"docs={n_docs};words_per_s={len(corpus['tokens'])/dt:.0f}")

    # Figure 19: scale-out (subprocesses, fake devices)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    for n_dev in (1, 2, 4, 8):
        try:
            out = subprocess.run(
                [sys.executable, "-c", _SCALE_OUT_SNIPPET, str(n_dev)],
                capture_output=True, text=True, timeout=900, env=env)
            line = [l for l in out.stdout.splitlines()
                    if l.startswith("PER_ITER_US")]
            us = float(line[0].split()[1]) if line else float("nan")
        except Exception:
            us = float("nan")
        report(f"vmp_scaleout_{n_dev}dev", us,
               "strategy=inferspark;note=fake_cpu_devices_1core")
