"""Multi-host distributed SVI: scaling curves across host topologies on the
out-of-core benchmark corpus, with per-host working-set accounting.

The corpus is bench_outofcore's largest single-host run (19200 docs /
~2.3M tokens, written shard by shard), so the multi-host numbers are
directly comparable to the single-host trajectory.  Each topology runs in
a child interpreter (jax locks its process/device topology at first init):

  ``single``    1 process, no partitioning — the baseline
  ``virtual2``  1 process, 2 virtual hosts over 2 fake CPU devices —
                partitioned batching, same SPMD program as the real thing
  ``2proc``     2 real ``jax.distributed`` processes (gloo CPU
                collectives), one device each — every host mmaps ONLY its
                owned shards

Per host we report us/step, tokens/s, and the working set the multi-host
design bounds: ``lengths.nbytes`` (global metadata, replicated) +
``peak_buffer_bytes`` (double-buffered batch host arrays) +
``owned_disk_bytes`` (the page-cache ceiling — only owned shards are ever
mapped).  A topology whose runtime cannot initialize (no gloo, no free
port) reports a ``skipped`` row instead of failing the bench.
"""

from __future__ import annotations

import json
import os
import shutil
import socket
import subprocess
import sys
import tempfile
import time

from benchmarks.bench_outofcore import (RESIDENT_DOCS, SCALE, V, _chunk,
                                        _planted_phi)

N_STEPS = 30
BATCH = 256


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


# ---------------------------------------------------------------------------
# child: one host of one topology
# ---------------------------------------------------------------------------

def _child(topo: str, pid: int, n_hosts: int, port: int, corpus_dir: str,
           out_path: str, steps: int) -> None:
    import jax

    from repro.compat import make_mesh
    from repro.core import models
    from repro.core.partition import ShardingPlan
    from repro.core.svi import SVI, SVIConfig
    from repro.data import HostAssignment, ShardedCorpus

    hosts = None
    if topo == "2proc":
        from repro.compat import distributed_initialize
        distributed_initialize(f"127.0.0.1:{port}", n_hosts, pid)
        hosts = HostAssignment(n_hosts, jax.process_index())
        corpus = ShardedCorpus.open(corpus_dir, hosts=hosts)
    else:
        corpus = ShardedCorpus.open(corpus_dir)
        if topo == "virtual2":
            hosts = HostAssignment(n_hosts, 0)
    plan = None
    if hosts is not None:
        mesh = make_mesh((jax.device_count(),), ("data",))
        plan = ShardingPlan(mesh, ("data",), "inferspark")
    cfg = SVIConfig(batch_size=BATCH, holdout_frac=0.0, pad_multiple=2048,
                    seed=0)
    svi = SVI(models.make("lda", alpha=0.1, beta=0.05, K=16, V=V), cfg,
              plan=plan, corpus=corpus, hosts=hosts)
    state, _ = svi.fit(steps=2)                  # compile + warm the caches
    t0 = time.time()
    state, _ = svi.fit(steps=steps, state=state)
    dt = time.time() - t0
    svi.close()
    tokens_per_step = corpus.n_tokens / svi.sampler.batches_per_epoch
    working_set = (corpus.lengths.nbytes + svi.sampler.peak_buffer_bytes
                   + corpus.owned_disk_bytes)
    with open(out_path, "w") as fh:
        json.dump({
            "topo": topo, "host": pid, "n_hosts": n_hosts,
            "us_per_step": dt / steps * 1e6,
            "tokens_per_s": tokens_per_step * steps / dt,
            "peak_buffer_bytes": int(svi.sampler.peak_buffer_bytes),
            "lengths_bytes": int(corpus.lengths.nbytes),
            "owned_disk_bytes": int(corpus.owned_disk_bytes),
            "owned_shards": int(len(corpus.owned_shards())),
            "n_shards": int(corpus.n_shards),
            "disk_bytes": int(corpus.disk_bytes),
            "working_set_bytes": int(working_set),
            "n_docs": int(corpus.n_docs), "n_tokens": int(corpus.n_tokens),
        }, fh)
    print("BENCH CHILD DONE", topo, pid)


def _spawn(topo: str, pid: int, n_hosts: int, port: int, corpus_dir: str,
           out_path: str) -> subprocess.Popen:
    env = dict(os.environ)
    if topo == "virtual2":
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    else:
        env.pop("XLA_FLAGS", None)
    return subprocess.Popen(
        [sys.executable, "-m", "benchmarks.bench_multihost", "--child",
         topo, str(pid), str(n_hosts), str(port), corpus_dir, out_path,
         str(N_STEPS)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)


# ---------------------------------------------------------------------------
# parent: corpus + topology sweep
# ---------------------------------------------------------------------------

def run(report):
    phi_cdf = _planted_phi().cumsum(axis=1)
    tmp = tempfile.mkdtemp(prefix="bench_multihost_")
    try:
        from repro.data import ShardedCorpusWriter
        n_chunks, chunk_docs = SCALE * 2, RESIDENT_DOCS // 2
        w = ShardedCorpusWriter(os.path.join(tmp, "corpus"),
                                shard_tokens=1 << 17, vocab=V)
        for i in range(n_chunks):
            tokens, lengths = _chunk(phi_cdf, chunk_docs, chunk_seed=i + 1)
            w.add_docs(tokens, lengths)
        corpus = w.close()
        assert corpus.n_docs == SCALE * RESIDENT_DOCS
        report("multihost_corpus", 0.0,
               f"docs={corpus.n_docs};tokens={corpus.n_tokens};"
               f"shards={corpus.n_shards};"
               f"disk_mb={corpus.disk_bytes / 1e6:.1f}")

        results: dict[str, list[dict]] = {}
        for topo, n_hosts, n_procs in (("single", 1, 1), ("virtual2", 2, 1),
                                       ("2proc", 2, 2)):
            port = _free_port()
            outs = [os.path.join(tmp, f"{topo}.{p}.json")
                    for p in range(n_procs)]
            procs = [_spawn(topo, p, n_hosts, port,
                            os.path.join(tmp, "corpus"), outs[p])
                     for p in range(n_procs)]
            errs = []
            for p in procs:
                try:
                    _, err = p.communicate(timeout=1200)
                except subprocess.TimeoutExpired:
                    p.kill()
                    _, err = p.communicate()
                errs.append(err)
            if any(p.returncode != 0 for p in procs):
                tail = "; ".join((e or "").strip().splitlines()[-1]
                                 if (e or "").strip() else "?"
                                 for e in errs)[:200].replace(",", " ")
                report(f"multihost_{topo}_skipped", 0.0,
                       f"reason={tail}")
                continue
            results[topo] = [json.load(open(o)) for o in outs]

        base = results.get("single", [{}])[0].get("tokens_per_s")
        for topo, rows in results.items():
            agg_tok = rows[0]["tokens_per_s"]   # global schedule: identical
            for r in rows:
                speedup = (agg_tok / base) if base else float("nan")
                report(
                    f"multihost_{topo}_host{r['host']}", r["us_per_step"],
                    f"tokens_per_s={r['tokens_per_s']:.0f};"
                    f"speedup_vs_single={speedup:.3f};"
                    f"working_set_mb={r['working_set_bytes'] / 1e6:.2f};"
                    f"owned_disk_mb={r['owned_disk_bytes'] / 1e6:.2f};"
                    f"owned_shards={r['owned_shards']}/{r['n_shards']};"
                    f"peak_buffer_mb={r['peak_buffer_bytes'] / 1e6:.2f}",
                    **{k: r[k] for k in
                       ("topo", "host", "n_hosts", "tokens_per_s",
                        "working_set_bytes", "owned_disk_bytes",
                        "peak_buffer_bytes", "lengths_bytes",
                        "owned_shards", "n_shards", "n_docs", "n_tokens")})

        # the design's working-set claim: a real multi-host host maps only
        # its owned shards — strictly less disk exposure than the baseline
        if "2proc" in results and "single" in results:
            whole = results["single"][0]["owned_disk_bytes"]
            for r in results["2proc"]:
                assert r["owned_disk_bytes"] < whole, (
                    f"host {r['host']} maps the whole corpus")
            covered = sum(r["owned_disk_bytes"] for r in results["2proc"])
            assert covered == whole, "owned shards do not partition the disk"
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    if len(sys.argv) >= 2 and sys.argv[1] == "--child":
        _, _, topo, pid, n_hosts, port, corpus_dir, out_path, steps = \
            sys.argv
        _child(topo, int(pid), int(n_hosts), int(port), corpus_dir,
               out_path, int(steps))
    else:
        run(lambda name, us, derived="", **_:
            print(f"{name},{us:.2f},{derived}"))
