"""Out-of-core SVI: fit a corpus 8x the largest resident benchmark corpus
(bench_svi runs 2400 docs / ~288k tokens resident; this streams 19200 docs /
~2.3M tokens from disk shards) to the same held-out per-token ELBO target,
with the resident corpus working set bounded by the shard read buffers —
the lengths array plus at most two minibatches' host arrays (the double
buffer), independent of corpus size.

Protocol:

1. *Ingestion* — the corpus is written chunk by chunk through
   ``ShardedCorpusWriter`` (shared planted topics across chunks), so the
   full token array is never resident, start to finish.
2. *Target* — a short full-batch VMP run (via the engine API, resident) on
   a 2400-doc corpus drawn from the same planted topics sets the held-out
   per-token ELBO target, exactly as ``bench_svi`` does.
3. *Streaming fit* — sharded SVI streams document minibatches from the
   shards (double-buffered prefetch) until the held-out ELBO matches the
   target within tolerance.
4. *Evidence* — reported rows: steps/time to target, bytes read vs corpus
   bytes, and ``peak resident / corpus bytes`` (asserted < 1/8); plus a
   bitwise sharded-vs-resident check on a small corpus (asserted equal).
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import SVI, SVIConfig, make_engine, models
from repro.data import ShardedCorpusWriter, write_sharded_corpus
from repro.data.store import _tree_nbytes, slice_sharded

TOL = 0.03            # nats/token slack on the target (holdout docs differ)
K, V = 16, 2000
ALPHA, BETA, MEAN_LEN = 0.1, 0.05, 120
RESIDENT_DOCS = 2400  # bench_svi's corpus — the largest resident benchmark
SCALE = 8


def _planted_phi(seed: int = 0) -> np.ndarray:
    """The (K, V) planted topics — drawn once, shared by every chunk (and
    identical to SyntheticCorpus(seed=0)'s, which draws phi first)."""
    rng = np.random.default_rng(seed)
    return rng.dirichlet(np.full(V, BETA), size=K)


def _chunk(phi_cdf: np.ndarray, n_docs: int, chunk_seed: int):
    """Generate one chunk of documents against fixed topics: theta_d ~
    Dir(alpha), z ~ theta_d, token ~ phi_z (SyntheticCorpus's process with
    the topic draw hoisted out so chunks share phi)."""
    rng = np.random.default_rng(np.random.SeedSequence([909, chunk_seed]))
    theta = rng.dirichlet(np.full(K, ALPHA), size=n_docs)
    lengths = np.maximum(rng.poisson(MEAN_LEN, size=n_docs), 2) \
        .astype(np.int64)
    n = int(lengths.sum())
    z = np.empty(n, np.int32)
    start = 0
    for d, ln in enumerate(lengths):
        z[start:start + ln] = rng.choice(K, size=ln, p=theta[d])
        start += ln
    u = rng.random(n)
    tokens = np.empty(n, np.int32)
    for k in range(K):
        m = z == k
        tokens[m] = np.searchsorted(phi_cdf[k], u[m]).astype(np.int32)
    return np.minimum(tokens, V - 1), lengths


def _model():
    return models.make("lda", alpha=ALPHA, beta=BETA, K=K, V=V)


def run(report):
    phi = _planted_phi()
    phi_cdf = np.cumsum(phi, axis=1)
    tmp = tempfile.mkdtemp(prefix="bench_outofcore_")
    try:
        # -- 1. streaming ingestion: 8x the resident corpus, chunk by chunk
        n_chunks, chunk_docs = SCALE * 2, RESIDENT_DOCS // 2
        t0 = time.time()
        w = ShardedCorpusWriter(os.path.join(tmp, "corpus"),
                                shard_tokens=1 << 17, vocab=V)
        for i in range(n_chunks):
            tokens, lengths = _chunk(phi_cdf, chunk_docs, chunk_seed=i + 1)
            w.add_docs(tokens, lengths)
        corpus = w.close()
        t_write = time.time() - t0
        report("outofcore_write", t_write / n_chunks * 1e6,
               f"docs={corpus.n_docs};tokens={corpus.n_tokens};"
               f"shards={corpus.n_shards};"
               f"disk_mb={corpus.disk_bytes / 1e6:.1f}")
        assert corpus.n_docs == SCALE * RESIDENT_DOCS

        # -- 2. resident target: short full-batch VMP at bench_svi's scale
        tokens, lengths = _chunk(phi_cdf, RESIDENT_DOCS, chunk_seed=0)
        m = _model()
        m["x"].observe(tokens, lengths=lengths)
        t0 = time.time()
        vmp = make_engine("vmp", steps=15, holdout_frac=0.02, seed=0).fit(m)
        t_vmp = time.time() - t0
        target = vmp.heldout_elbo
        report("outofcore_target_heldout_elbo_vmp15", t_vmp / 15 * 1e6,
               f"resident_tokens={len(tokens)};target={target:.4f};"
               f"vmp_total_s={t_vmp:.1f}")

        # -- 3. stream minibatches from the shards until the target.
        # local_iters > 1 matters here: at G/|B| ~ 150 the natural-gradient
        # targets are noisy, and under-converged local (theta) rows poison
        # the global stats; a few extra local passes per batch (Hoffman et
        # al. run locals to convergence) let |B|=128 reach the full-batch
        # target in tens of steps where local_iters=1 plateaus for hundreds.
        cfg = SVIConfig(batch_size=128, local_iters=5, holdout_frac=0.01,
                        holdout_every=5, pad_multiple=2048, kappa=0.7,
                        tau=1.0, seed=0)
        svi = SVI(_model(), cfg, corpus=corpus)
        state = None
        reached, steps_done, h = None, 0, float("-inf")
        t0 = time.time()
        while steps_done < 300 and reached is None:
            state, hist = svi.fit(steps=5, state=state)
            steps_done += 5
            h = hist["heldout"][-1][1]
            if h >= target - TOL:
                reached = steps_done
        t_svi = time.time() - t0
        svi.close()
        report("outofcore_steps_to_target",
               (t_svi / max(steps_done, 1)) * 1e6,
               f"steps={reached};heldout={h:.4f};target={target:.4f};"
               f"svi_total_s={t_svi:.1f};corpus_x_resident={SCALE}")

        # -- 4a. resident working set: lengths + the double-buffered batch
        # host arrays + one held-out slice — everything the fit ever holds
        # of the corpus at once (shards stay on disk, mmap'd read-only)
        heldout_bytes = _tree_nbytes(list(
            slice_sharded(svi.program, corpus, svi.holdout, None)[:2]))
        peak = (corpus.lengths.nbytes + svi.sampler.peak_buffer_bytes
                + heldout_bytes)
        ratio = peak / corpus.disk_bytes
        report("outofcore_working_set", peak,
               f"peak_resident_mb={peak / 1e6:.2f};"
               f"corpus_mb={corpus.disk_bytes / 1e6:.1f};"
               f"ratio={ratio:.4f};"
               f"bytes_read_mb={corpus.bytes_read / 1e6:.1f};"
               f"prefetch_buf_mb={svi.sampler.peak_buffer_bytes / 1e6:.2f}")

        # -- 4b. bitwise: sharded and resident SVI agree exactly
        small_tokens, small_lengths = _chunk(phi_cdf, 300, chunk_seed=77)
        small = write_sharded_corpus(
            {"tokens": small_tokens, "lengths": small_lengths},
            os.path.join(tmp, "small"), shard_tokens=1 << 13, vocab=V)
        m = _model()
        m["x"].observe(small_tokens, lengths=small_lengths)
        scfg = SVIConfig(batch_size=32, holdout_frac=0.1, holdout_every=0,
                         pad_multiple=256, seed=0)
        s_res, _ = SVI(m.compile(), scfg).fit(steps=8)
        sh = SVI(_model(), scfg, corpus=small)
        s_sh, _ = sh.fit(steps=8)
        sh.close()
        bitwise = all(
            np.array_equal(np.asarray(s_res.posteriors[n]),
                           np.asarray(s_sh.posteriors[n]))
            for n in s_res.posteriors)
        report("outofcore_bitwise_small", float(bitwise),
               f"equal={int(bitwise)};docs=300;steps=8")

        assert reached is not None, (
            f"sharded SVI failed to reach target {target:.4f} (got {h:.4f})")
        assert ratio < 1 / SCALE, (
            f"resident working set {peak} bytes is not bounded: "
            f"{ratio:.3f} of the {corpus.disk_bytes}-byte corpus")
        assert bitwise, "sharded and resident SVI posteriors diverged"
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
