"""Gateway benchmark: mixed-tenant load over two artifacts, and the
compacted-replica trade (size vs latency vs measured error).

Protocol: fit a short SVI run, freeze the posterior, compact a replica
(top-k + bf16, ``repro.gateway.compact``), register *both* under one
:class:`~repro.gateway.Gateway`, then —

  - **mixed-tenant load**: T tenant threads each run a mixed QL script
    (TOPICS / SIMILARITY / CREDIBLE INTERVAL / PREDICT) against both
    artifacts through the admission-controlled front door; reports
    end-to-end us/query, windowed qps, and p95 latency from the
    gateway's own stats tree (the accounting a deployment would watch);
  - **compacted vs full**: the same statements against the full and the
    compacted artifact — per-query-kind latency, artifact byte sizes
    (``>= 4x`` smaller is the bar), the recorded worst-case
    total-variation bound on the mean tables, and the realized PREDICT
    per-token-ll deviation between the replicas (reported raw, next to
    the bound).
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import make_engine, models
from repro.data import SyntheticCorpus
from repro.gateway import Gateway, compact_posterior

K, V = 16, 2000
N_TENANTS = 4
QUERIES_PER_TENANT = 24
TOP_K = 128


def _fit_posterior():
    corpus = SyntheticCorpus(n_docs=400, vocab=V, n_topics=K,
                             mean_len=100, seed=0).generate()
    m = models.make("lda", alpha=0.1, beta=0.05, K=K, V=V)
    m["x"].observe(corpus["tokens"], segment_ids=corpus["doc_ids"])
    result = make_engine("svi", steps=25, batch_size=128, seed=0).fit(m)
    return result.freeze(m), corpus


def _docs(corpus, seed, n=3):
    rng = np.random.default_rng(seed)
    offs = np.concatenate([[0], np.cumsum(corpus["lengths"])])
    picks = rng.integers(0, len(corpus["lengths"]), n)
    vals = np.concatenate([corpus["tokens"][offs[i]:offs[i + 1]]
                           for i in picks])
    return {"values": vals, "lengths": corpus["lengths"][picks]}


_SCRIPT = """
    TOPICS OF phi TOP 10 USING ARTIFACT '{a}';
    SIMILARITY BETWEEN phi[0] AND phi[1] USING hellinger
        USING ARTIFACT '{a}';
    CREDIBLE INTERVAL 0.9 FOR phi[0] USING ARTIFACT '{a}';
    PREDICT LL FOR DOCS $batch USING ARTIFACT '{a}'
"""


def run(report) -> None:
    post, corpus = _fit_posterior()
    comp = compact_posterior(post, top_k=TOP_K)
    report("gateway_compact_size", 0.0,
           f"{comp.compression_ratio():.1f}x smaller",
           bytes_full=comp.nbytes_full(),
           bytes_compact=comp.nbytes_compact(),
           error_bound=comp.error_bound)

    with Gateway(max_delay_s=0.002) as gw:
        gw.register("full", post, version="f0")
        gw.register("lite", comp, version="l0")

        # warm both artifacts' compiled buckets out of the measurement
        for aid in ("full", "lite"):
            gw.query(f"PREDICT LL FOR DOCS $batch USING ARTIFACT '{aid}'",
                     params={"batch": _docs(corpus, 0)}, timeout_s=120)

        # -- mixed-tenant load over both artifacts -------------------------
        errors = []

        def tenant_load(tenant, seed):
            rng = np.random.default_rng(seed)
            for i in range(QUERIES_PER_TENANT // 4):
                aid = ("full", "lite")[int(rng.integers(2))]
                try:
                    gw.run_script(
                        _SCRIPT.format(a=aid),
                        params={"batch": _docs(corpus, seed * 97 + i)},
                        tenant=tenant, timeout_s=120)
                except Exception as e:            # pragma: no cover
                    errors.append((tenant, repr(e)))

        threads = [threading.Thread(target=tenant_load,
                                    args=(f"tenant-{t}", t))
                   for t in range(N_TENANTS)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        assert not errors, errors[:3]

        stats = gw.stats()
        total = sum(t["served"] for t in stats["tenants"].values())
        p95 = max(t["latency_p95_ms"] for t in stats["tenants"].values())
        occ = [a.get("batch_occupancy")
               for a in stats["artifacts"].values()
               if a.get("batch_occupancy")]
        report("gateway_mixed_tenant_load", wall / total * 1e6,
               f"{total / wall:.0f} qps, p95 {p95:.1f} ms",
               tenants=N_TENANTS, queries=total,
               p95_ms=round(p95, 2),
               mean_batch_occupancy=(round(float(np.mean(occ)), 2)
                                     if occ else None))

        # -- compacted vs full, per query kind -----------------------------
        lls = {}
        for aid in ("full", "lite"):
            for label, text in [
                    ("topics", f"TOPICS OF phi TOP 10 "
                               f"USING ARTIFACT '{aid}'"),
                    ("predict", f"PREDICT LL FOR DOCS $batch "
                                f"USING ARTIFACT '{aid}'")]:
                reps, t0 = 20, time.perf_counter()
                for i in range(reps):
                    r = gw.query(text, params={"batch": _docs(corpus, i)},
                                 timeout_s=120)
                us = (time.perf_counter() - t0) / reps * 1e6
                if label == "predict":
                    lls[aid] = r.value["per_token_ll"]
                extra = {}
                if aid == "lite":
                    extra["error_bound"] = r.error_bound
                report(f"gateway_{label}_{aid}", us,
                       f"served by {r.version}", **extra)
        dev = abs(lls["lite"] - lls["full"])
        report("gateway_predict_ll_deviation", 0.0,
               f"|lite-full| = {dev:.4f} nats/token",
               ll_full=round(lls["full"], 6), ll_lite=round(lls["lite"], 6),
               error_bound=comp.error_bound)
