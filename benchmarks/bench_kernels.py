"""Kernel-level microbenchmarks: the VMP hot-loop primitives.

Times the production path (jnp oracle on CPU; the Pallas kernels target TPU
and are validated for correctness in interpret mode by tests).  Derived
column reports achieved elements/s and the HBM traffic the fused kernels
remove (see docs/performance.md for the traffic model).

The headline case is ``kernel_zstats_*``: the fused one-pass
gather->softmax->stats substep (``ref.zstats``, the production step body's
path) against the unfused gather + zstep + segment_sum chain it replaced —
the chain materializes the (N, K) logits and responsibilities, the fused
pass streams them chunk-at-a-time.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _time(fn, *args, iters=20):
    """Min-of-iters wall time: robust to scheduler noise on shared hosts."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        best = min(best, time.time() - t0)
    return best


def _lda_corpus(rng, n, k, d, v):
    toks = jnp.asarray(rng.integers(0, v, n).astype(np.int32))
    docs = jnp.asarray(np.sort(rng.integers(0, d, n)).astype(np.int32))
    et = jnp.asarray(rng.normal(size=(d, k)).astype(np.float32))
    ep = jnp.asarray(rng.normal(size=(k, v)).astype(np.float32))
    return toks, docs, et, ep


def _zstats_hbm_bytes(n, k, d, v, streamed=False):
    """Per-call HBM bytes of the token-plate substep (fp32, TPU model).

    unfused: 2 (N,K) gather reads + write/read logits + write r + 2 r
    re-reads (one per stats scatter) + stats accumulator traffic.
    fused:   token index streams (the tables are VMEM-resident and the
    (N, K) intermediates never leave VMEM) + one stats flush.
    streamed (tables too large for VMEM): the over-budget table's tiles
    are each read once per step and its accumulator flushed per tile —
    same 2x table words — plus the trace-time bucketing permutation
    (~1 extra token-stream round trip).  See docs/performance.md.
    """
    tables = d * k + k * v
    unfused = 4 * (7 * n * k + 2 * tables)
    fused = 4 * ((3 if streamed else 2) * n + 2 * tables)
    return unfused, fused


def _zmap_hbm_bytes(nt, nz, k, d, v):
    """Two-phase segment-latent model: the unfused chain round-trips the
    (N_token, K) message and gathered-responsibility arrays; the fused
    kernel touches the token streams twice (logits phase, stats phase) and
    round-trips only the (n_latent, K) logits/responsibilities."""
    tables = d * k + k * v
    unfused = 4 * (5 * nt * k + 4 * nz * k + 2 * tables)
    fused = 4 * (4 * nt + 4 * nz * k + 2 * tables)
    return unfused, fused


def run(report):
    rng = np.random.default_rng(0)

    for g, k in ((100_000, 16), (1_000, 2_000), (96, 50_000)):
        a = jnp.asarray(rng.gamma(1.0, 1.0, (g, k)).astype(np.float32) + .01)
        f = jax.jit(ref.dirichlet_expectation)
        dt = _time(f, a)
        report(f"kernel_dirichlet_expectation_{g}x{k}", dt * 1e6,
               f"elems_per_s={g*k/dt:.3e}", dims={"g": g, "k": k})

    for n, k in ((500_000, 16), (100_000, 96)):
        x = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
        f = jax.jit(ref.zstep)
        dt = _time(f, x)
        # unfused = 3 HBM passes (max, exp/sum, div); fused kernel = 1
        report(f"kernel_zstep_{n}x{k}", dt * 1e6,
               f"rows_per_s={n/dt:.3e};fused_hbm_passes=1_vs_3",
               dims={"n": n, "k": k})

    # fused token-plate substep vs the chain it replaced, LDA-shaped.
    # Keep the largest (N, K) last: the acceptance gate for the fusion.
    for n, k, d, v in ((200_000, 64, 2_000, 10_000),
                       (600_000, 128, 5_000, 20_000)):
        toks, docs, et, ep = _lda_corpus(rng, n, k, d, v)

        def unfused(et, ep, docs, toks, d=d, v=v):
            logits = et[docs] + ep[:, toks].T
            r, lse = ref.zstep(logits)
            ts = jnp.zeros((d, et.shape[1]), jnp.float32).at[docs].add(r)
            ps = jax.ops.segment_sum(r, toks, num_segments=v).T
            return lse.sum(), ts, ps

        u = jax.jit(unfused)
        f = jax.jit(lambda et, ep, docs, toks:
                    ref.zstats(et, docs, (ref.ZChild(ep, toks, 1),)))
        dt_u = _time(u, et, ep, docs, toks, iters=8)
        dt_f = _time(f, et, ep, docs, toks, iters=8)
        b_u, b_f = _zstats_hbm_bytes(n, k, d, v)
        dims = {"n": n, "k": k, "d": d, "v": v}
        report(f"kernel_zstats_unfused_{n}x{k}", dt_u * 1e6,
               f"tokens_per_s={n/dt_u:.3e};hbm_bytes={b_u:.3e}", dims=dims)
        report(f"kernel_zstats_fused_{n}x{k}", dt_f * 1e6,
               f"tokens_per_s={n/dt_f:.3e};hbm_bytes={b_f:.3e};"
               f"hbm_bytes_ratio={b_u/b_f:.1f};"
               f"speedup_vs_unfused={dt_u/dt_f:.2f}", dims=dims)

    # large-vocabulary LDA: phi's padded footprint (~2.5x _TABLE_BUDGET)
    # takes the HBM-streamed kernel on TPU (tiled tables, bucketed tokens);
    # this CPU path times the same fused semantics via the chunked oracle.
    for n, k, d, v in ((400_000, 32, 2_000, 60_000),):
        toks, docs, et, ep = _lda_corpus(rng, n, k, d, v)

        def unfused(et, ep, docs, toks, d=d, v=v):
            logits = et[docs] + ep[:, toks].T
            r, lse = ref.zstep(logits)
            ts = jnp.zeros((d, et.shape[1]), jnp.float32).at[docs].add(r)
            ps = jax.ops.segment_sum(r, toks, num_segments=v).T
            return lse.sum(), ts, ps

        u = jax.jit(unfused)
        f = jax.jit(lambda et, ep, docs, toks:
                    ref.zstats(et, docs, (ref.ZChild(ep, toks, 1),)))
        dt_u = _time(u, et, ep, docs, toks, iters=8)
        dt_f = _time(f, et, ep, docs, toks, iters=8)
        b_u, b_f = _zstats_hbm_bytes(n, k, d, v, streamed=True)
        dims = {"n": n, "k": k, "d": d, "v": v}
        report(f"kernel_zstats_unfused_largev_{n}x{v}", dt_u * 1e6,
               f"tokens_per_s={n/dt_u:.3e};hbm_bytes={b_u:.3e}", dims=dims)
        report(f"kernel_zstats_fused_largev_{n}x{v}", dt_f * 1e6,
               f"tokens_per_s={n/dt_f:.3e};hbm_bytes={b_f:.3e};"
               f"hbm_bytes_ratio={b_u/b_f:.1f};"
               f"speedup_vs_unfused={dt_u/dt_f:.2f}", dims=dims)

    # segment latents (SLDA-shaped zmap): on TPU the two-phase fused_zmap
    # kernel; the unfused chain materializes the (N_token, K) messages and
    # the r[zmap] expansion.
    for nt, nz, k, d, v in ((400_000, 40_000, 32, 2_000, 10_000),):
        toks = jnp.asarray(rng.integers(0, v, nt).astype(np.int32))
        tsent = jnp.asarray(np.sort(rng.integers(0, nz, nt))
                            .astype(np.int32))
        sdoc = jnp.asarray(np.sort(rng.integers(0, d, nz))
                           .astype(np.int32))
        et = jnp.asarray(rng.normal(size=(d, k)).astype(np.float32))
        ep = jnp.asarray(rng.normal(size=(k, v)).astype(np.float32))

        def unfused(et, ep, sdoc, toks, tsent, nz=nz, d=d, v=v):
            msgs = ep[:, toks].T                       # (N_token, K)
            logits = et[sdoc] + jax.ops.segment_sum(msgs, tsent,
                                                    num_segments=nz)
            r, lse = ref.zstep(logits)
            ts = jnp.zeros((d, et.shape[1]), jnp.float32).at[sdoc].add(r)
            w = r[tsent]                               # (N_token, K)
            ps = jax.ops.segment_sum(w, toks, num_segments=v).T
            return lse.sum(), ts, ps

        u = jax.jit(unfused)
        f = jax.jit(lambda et, ep, sdoc, toks, tsent:
                    ref.zstats(et, sdoc,
                               (ref.ZChild(ep, toks, 1, zmap=tsent),)))
        dt_u = _time(u, et, ep, sdoc, toks, tsent, iters=8)
        dt_f = _time(f, et, ep, sdoc, toks, tsent, iters=8)
        b_u, b_f = _zmap_hbm_bytes(nt, nz, k, d, v)
        dims = {"nt": nt, "nz": nz, "k": k, "d": d, "v": v}
        report(f"kernel_zstats_zmap_unfused_{nt}x{k}", dt_u * 1e6,
               f"tokens_per_s={nt/dt_u:.3e};hbm_bytes={b_u:.3e}",
               dims=dims)
        report(f"kernel_zstats_zmap_fused_{nt}x{k}", dt_f * 1e6,
               f"tokens_per_s={nt/dt_f:.3e};hbm_bytes={b_f:.3e};"
               f"hbm_bytes_ratio={b_u/b_f:.1f};"
               f"speedup_vs_unfused={dt_u/dt_f:.2f}", dims=dims)
