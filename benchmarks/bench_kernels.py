"""Kernel-level microbenchmarks: the VMP hot-loop primitives.

Times the production path (jnp oracle on CPU; the Pallas kernels target TPU
and are validated for correctness in interpret mode by tests).  Derived
column reports achieved elements/s and the arithmetic intensity the kernel
removes (fused vs unfused HBM passes).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(report):
    rng = np.random.default_rng(0)

    for g, k in ((100_000, 16), (1_000, 2_000), (96, 50_000)):
        a = jnp.asarray(rng.gamma(1.0, 1.0, (g, k)).astype(np.float32) + .01)
        f = jax.jit(ref.dirichlet_expectation)
        dt = _time(f, a)
        report(f"kernel_dirichlet_expectation_{g}x{k}", dt * 1e6,
               f"elems_per_s={g*k/dt:.3e}")

    for n, k in ((500_000, 16), (100_000, 96)):
        x = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
        f = jax.jit(ref.zstep)
        dt = _time(f, x)
        # unfused = 3 HBM passes (max, exp/sum, div); fused kernel = 1
        report(f"kernel_zstep_{n}x{k}", dt * 1e6,
               f"rows_per_s={n/dt:.3e};fused_hbm_passes=1_vs_3")
