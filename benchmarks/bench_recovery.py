"""Crash-safety cost: what fault tolerance charges the hot paths.

Four numbers (the ``docs/fault_tolerance.md`` acceptance accounting):

1. *Checkpoint overhead* — the same SVI fit with and without session
   checkpointing (async commit, every 5 steps): the %% the training loop
   pays for durability.
2. *Per-save cost* — one blocking self-validating checkpoint commit
   (serialize + checksum + fsync + atomic replace) of a session-sized
   tree, in ms.
3. *Resume latency* — crash-to-training-again: load + validate the newest
   session, rebuild (state, sampler cursor, holdout), and run the first
   step (includes the re-jit a fresh process pays).
4. *Writer reopen* — adopting a committed sharded store after a writer
   crash (manifest adoption + orphan sweep + per-shard header checks).
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import SVI, SVIConfig, models
from repro.data import ShardedCorpus, ShardedCorpusWriter

K, V, N_DOCS, MEAN_LEN = 8, 500, 400, 80
STEPS, EVERY = 40, 5


def _corpus(seed: int = 0):
    from repro.data import SyntheticCorpus
    return SyntheticCorpus(n_docs=N_DOCS, vocab=V, n_topics=K,
                           mean_len=MEAN_LEN, seed=seed).generate()


def _svi(corpus):
    m = models.make("lda", alpha=0.1, beta=0.05, K=K, V=V)
    m["x"].observe(corpus["tokens"], segment_ids=corpus["doc_ids"])
    return SVI(m.compile(), SVIConfig(batch_size=64, holdout_frac=0.05,
                                      holdout_every=10, seed=0))


def run(report):
    corpus = _corpus()
    tmp = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        # -- 1. checkpoint overhead on the training loop
        svi = _svi(corpus)
        svi.fit(steps=12)   # compile (incl. the holdout eval at step 10)
                            # outside the timings
        t0 = time.time()
        svi.fit(steps=STEPS)
        t_plain = time.time() - t0
        d = os.path.join(tmp, "ck")
        t0 = time.time()
        svi.fit(steps=STEPS, checkpoint_dir=d, checkpoint_every=EVERY)
        t_ck = time.time() - t0
        overhead = (t_ck - t_plain) / t_plain * 100.0
        n_saves = STEPS // EVERY
        report("recovery_checkpoint_overhead", t_ck / STEPS * 1e6,
               f"overhead_pct={overhead:.1f};plain_us="
               f"{t_plain / STEPS * 1e6:.0f};saves={n_saves};every={EVERY}",
               overhead_pct=round(overhead, 2))

        # -- 2. one blocking self-validating commit of a session-sized tree
        from repro.checkpoint import session as _session
        from repro.checkpoint import store as _store
        state, history = svi.fit(steps=1)
        sess = svi._snapshot_session(state, history)
        tree, meta = _session._to_tree(sess), _session._meta(sess)
        nbytes = sum(np.asarray(v).nbytes
                     for v in (tree["posteriors"] |
                               {k: v for k, v in tree.items()
                                if k != "posteriors"}).values())
        d2 = os.path.join(tmp, "save")
        reps, t0 = 5, time.time()
        for i in range(reps):
            _store.save(d2, i, tree, meta=meta)
        per_save = (time.time() - t0) / reps
        report("recovery_session_save", per_save * 1e6,
               f"ms={per_save * 1e3:.2f};bytes={nbytes};"
               f"mb_per_s={nbytes / per_save / 1e6:.0f}",
               save_ms=round(per_save * 1e3, 3))

        # -- 3. crash-to-training-again latency (fresh process stand-in:
        #       a new SVI instance pays validate + adopt + re-jit + step 1)
        svi.close()
        t0 = time.time()
        fresh = _svi(corpus)
        fresh.fit(steps=1, checkpoint_dir=d, resume_from=True)
        t_resume = time.time() - t0
        t0 = time.time()
        _session.load_session(d)
        t_load = time.time() - t0
        report("recovery_resume_latency", t_resume * 1e6,
               f"total_ms={t_resume * 1e3:.0f};"
               f"load_validate_ms={t_load * 1e3:.2f}",
               resume_ms=round(t_resume * 1e3, 1))
        fresh.close()

        # -- 4. writer reopen (manifest adoption + header checks)
        cdir = os.path.join(tmp, "corpus")
        lengths = np.asarray(corpus["lengths"], np.int64)
        w = ShardedCorpusWriter(cdir, shard_tokens=1 << 12, vocab=V)
        w.add_docs(corpus["tokens"], lengths)
        sc = w.commit()                       # writer "crashes" here
        n_shards = len(sc.manifest["shards"])
        reps, t0 = 10, time.time()
        for _ in range(reps):
            ShardedCorpusWriter.reopen(cdir)
        per_reopen = (time.time() - t0) / reps
        report("recovery_writer_reopen", per_reopen * 1e6,
               f"ms={per_reopen * 1e3:.2f};shards={n_shards};"
               f"docs={sc.n_docs}",
               reopen_ms=round(per_reopen * 1e3, 3))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
