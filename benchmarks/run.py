"""Benchmark registry — one module per paper table/figure.

    bench_vmp        Figure 17 + Table 4 (overall time, stage breakdown,
                     EM-LDA/MLlib baseline)
    bench_scaling    Figures 18-19 (scale-up / scale-out)
    bench_partition  Figure 20 + Tables 1-2 (partition strategies, analytic
                     + measured, replicated-memory anecdote)
    bench_kernels    VMP hot-loop primitives (fused zstats vs the unfused
                     gather+zstep+segment_sum chain)
    bench_svi        streaming SVI vs full-batch VMP at 4x the largest
                     full-batch corpus (held-out ELBO target + working set)
    bench_outofcore  sharded on-disk corpus at 8x bench_svi's, streamed to
                     the same held-out ELBO target at a bounded resident
                     working set (+ bitwise sharded-vs-resident check)
    bench_query      query/serving layer: fold-in throughput sweep across
                     batch sizes, cold-vs-warm compile, batched-vs-single
                     speedup (the serving acceptance bar)
    bench_streaming  always-on loop: append-while-training to the resident
                     held-out target (growing sampler + live commits) and
                     >= 3 hot artifact swaps under concurrent client load
                     (swap install latency, zero dropped requests)
    bench_recovery   crash-safety cost: checkpoint overhead on the training
                     loop, per-commit ms of a self-validating session save,
                     crash-to-training-again resume latency, writer reopen
    bench_multihost  multi-host SVI on bench_outofcore's corpus: single vs
                     2-virtual-host vs real 2-process (gloo) topologies —
                     us/step + tokens/s scaling and the per-host working
                     set (owned shards only)
    bench_gateway    multi-tenant gateway: mixed QL load over two
                     artifacts through admission control, and the
                     compacted-replica trade (size ratio, per-kind
                     latency, measured error bound vs realized PREDICT
                     deviation)

Prints ``name,us_per_call,derived`` CSV.  Select modules with
``python -m benchmarks.run [vmp|scaling|partition|kernels] ...``.

``--json`` additionally writes one ``BENCH_<module>.json`` per selected
module — ``{"module", "backend", "rows": [{"name", "us_per_call",
"derived", ...}]}`` — the machine-readable perf trajectory CI uploads as an
artifact so regressions are diffable across commits.
"""

from __future__ import annotations

import json
import sys


def main() -> None:
    from benchmarks import (bench_gateway, bench_kernels, bench_multihost,
                            bench_outofcore, bench_partition, bench_query,
                            bench_recovery, bench_scaling, bench_streaming,
                            bench_svi, bench_vmp)
    mods = {"vmp": bench_vmp, "scaling": bench_scaling,
            "partition": bench_partition, "kernels": bench_kernels,
            "svi": bench_svi, "outofcore": bench_outofcore,
            "query": bench_query, "streaming": bench_streaming,
            "recovery": bench_recovery, "multihost": bench_multihost,
            "gateway": bench_gateway}
    args = sys.argv[1:]
    json_mode = "--json" in args
    picks = [a for a in args if a in mods] or list(mods)

    try:
        from repro.kernels.ops import _backend
        backend = _backend()
    except Exception:                 # pragma: no cover - kernels optional
        backend = "unknown"

    print("name,us_per_call,derived")
    for p in picks:
        rows: list[dict] = []

        def report(name: str, us_per_call: float, derived: str = "",
                   **extra) -> None:
            print(f"{name},{us_per_call:.2f},{derived}")
            rows.append({"name": name, "us_per_call": round(us_per_call, 2),
                         "derived": derived, **extra})

        mods[p].run(report)
        if json_mode:
            path = f"BENCH_{p}.json"
            with open(path, "w") as fh:
                json.dump({"module": p, "backend": backend, "rows": rows},
                          fh, indent=1)
            print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
