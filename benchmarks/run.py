"""Benchmark registry — one module per paper table/figure.

    bench_vmp        Figure 17 + Table 4 (overall time, stage breakdown,
                     EM-LDA/MLlib baseline)
    bench_scaling    Figures 18-19 (scale-up / scale-out)
    bench_partition  Figure 20 + Tables 1-2 (partition strategies, analytic
                     + measured, replicated-memory anecdote)
    bench_kernels    VMP hot-loop primitives
    bench_svi        streaming SVI vs full-batch VMP at 4x the largest
                     full-batch corpus (held-out ELBO target + working set)

Prints ``name,us_per_call,derived`` CSV.  Select modules with
``python -m benchmarks.run [vmp|scaling|partition|kernels] ...``.
"""

from __future__ import annotations

import sys


def _report(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def main() -> None:
    from benchmarks import (bench_kernels, bench_partition, bench_scaling,
                            bench_svi, bench_vmp)
    mods = {"vmp": bench_vmp, "scaling": bench_scaling,
            "partition": bench_partition, "kernels": bench_kernels,
            "svi": bench_svi}
    picks = [a for a in sys.argv[1:] if a in mods] or list(mods)
    print("name,us_per_call,derived")
    for p in picks:
        mods[p].run(_report)


if __name__ == "__main__":
    main()
