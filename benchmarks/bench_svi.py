"""The streaming engine's headline claim: a fixed held-out ELBO on a corpus
4x the largest full-batch benchmark corpus (bench_scaling tops out at 600
docs / ~72k tokens; this runs 2400 docs / ~288k tokens), at a per-step
working set that scales with the minibatch, not the corpus.

Protocol: a short full-batch VMP run (same held-out split, via the engine
API) sets the target held-out per-token ELBO; SVI then streams document
minibatches until it matches the target within tolerance.  Reported
alongside: per-step time for both engines and the token working-set ratio
(max padded batch tokens / corpus tokens) — the memory-bound evidence.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import SVI, SVIConfig, make_engine, models
from repro.data import SyntheticCorpus

TOL = 0.02            # nats/token slack on the target


def _model(corpus, K, V):
    m = models.make("lda", alpha=0.1, beta=0.05, K=K, V=V)
    m["x"].observe(corpus["tokens"], segment_ids=corpus["doc_ids"])
    return m


def run(report):
    K, V = 16, 2000
    corpus = SyntheticCorpus(n_docs=2400, vocab=V, n_topics=K,
                             mean_len=120, seed=0).generate()
    n = len(corpus["tokens"])

    # target: held-out ELBO of a short full-batch run on the training slice
    t0 = time.time()
    vmp = make_engine("vmp", steps=15, holdout_frac=0.02, seed=0) \
        .fit(_model(corpus, K, V))
    t_vmp = time.time() - t0
    target = vmp.heldout_elbo
    report("svi_target_heldout_elbo_vmp15", t_vmp / 15 * 1e6,
           f"tokens={n};target={target:.4f};vmp_total_s={t_vmp:.1f}")

    cfg = SVIConfig(batch_size=128, holdout_frac=0.02, holdout_every=5,
                    pad_multiple=2048, kappa=0.7, tau=10.0, seed=0)
    svi = SVI(_model(corpus, K, V).compile(), cfg)
    state = None
    reached, steps_done, h = None, 0, float("-inf")
    t0 = time.time()
    while steps_done < 400 and reached is None:
        state, hist = svi.fit(steps=5, state=state)
        steps_done += 5
        h = hist["heldout"][-1][1]
        if h >= target - TOL:
            reached = steps_done
    t_svi = time.time() - t0

    # working set: largest padded batch token cap across compiled traces
    tok_caps = [dict(sig).get("x", 0) for sig in svi._steps]
    max_cap = max(tok_caps) if tok_caps else 0
    report("svi_steps_to_target", (t_svi / max(steps_done, 1)) * 1e6,
           f"steps={reached};heldout={h:.4f};target={target:.4f};"
           f"svi_total_s={t_svi:.1f}")
    report("svi_working_set_ratio", max_cap,
           f"batch_token_cap={max_cap};corpus_tokens={n};"
           f"ratio={max_cap / n:.4f}")
    assert reached is not None, (
        f"SVI failed to reach target {target:.4f} (got {h:.4f})")
    assert max_cap < n / 4, "working set should be a small fraction of N"
